//! The wire [`Client`]: [`RemoteClient`]`<T>` over any [`Transport`].
//!
//! One synchronous request at a time per client (open one client per
//! thread; the server handles connections concurrently). Speaks the
//! strict untrusted framing (`MAX_FRAME_LEN` enforced on read and write)
//! on whatever byte stream the transport produced — the Unix-domain
//! socket, or TCP after the transport's preshared-token HELLO handshake
//! — and decodes kind-tagged ERR frames back into typed
//! [`UniGpsError`](crate::error::UniGpsError) values.
//!
//! Two protocol features keep the client thin:
//!
//! * **`WAIT` long-poll** — [`Client::wait`] parks on the server (which
//!   blocks on the scheduler's completion condvar) instead of hammering
//!   `STATUS` in a 2 → 128 ms backoff loop like the old `ServeClient`
//!   did; one round trip per [`WAIT_SLICE`] of waiting, not ~500 status
//!   calls per second per waiter.
//! * **Chunked results** — [`Client::result`] reads the
//!   `RESULT_BEGIN / RESULT_CHUNK / RESULT_END` stream
//!   ([`read_result_stream_body`]), so result tables of any size up to
//!   the stream cap (full-scale `uk` columns included) arrive bit-exact;
//!   the single-frame ceiling and its typed-ERR consolation are gone. A
//!   failure *inside* a stream (cap, count, checksum) poisons the
//!   connection — later calls fail fast with a typed error instead of
//!   misreading leftover chunk frames as responses.

use crate::client::{wait_timeout_error, Client};
use crate::engine::RunResult;
use crate::error::Result;
use crate::ipc::protocol::{get_u64, put_u64, status};
use crate::ipc::socket_rpc::{call_limited, MAX_FRAME_LEN};
use crate::plan::wire::encode_plan;
use crate::plan::Plan;
use crate::serve::jobs::{decode_result, JobId, JobStatus};
use crate::serve::method;
use crate::serve::server::ServeStats;
use crate::serve::transport::{
    decode_error, read_result_stream_body, reply, Conn, TcpTransport, Transport, UdsTransport,
};
use std::io::{BufReader, BufWriter};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Longest wait a single `WAIT` round trip asks the server for. The
/// server clamps harder (its own cap); the client slices its deadline so
/// a dead server is noticed within one slice, not one full timeout.
pub const WAIT_SLICE: Duration = Duration::from_secs(10);

/// Client-side socket I/O timeout, applied to every connection this
/// client opens: a dead or wedged server surfaces as a typed I/O error
/// within this bound instead of hanging the caller forever. Must exceed
/// [`WAIT_SLICE`] (a healthy `WAIT` round trip keeps the socket quiet for
/// a full slice while the server parks on its condvar).
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Reconnect attempts after a transport failure before giving up
/// (exponential backoff between them, [`RECONNECT_BACKOFF`] × 4ⁿ).
pub const RECONNECT_ATTEMPTS: usize = 3;

/// Initial backoff between reconnect attempts.
pub const RECONNECT_BACKOFF: Duration = Duration::from_millis(25);

/// Client for a [`Server`](crate::serve::Server) over a connection
/// transport `T`. See the [module docs](self) for the protocol surface.
pub struct RemoteClient<T: Transport> {
    transport: T,
    reader: BufReader<Conn>,
    writer: BufWriter<Conn>,
    /// Set when a result stream failed mid-reassembly (cap, count or
    /// checksum violation): unread chunk frames may still be buffered,
    /// so the request/response pairing is gone. Every later call fails
    /// fast with a typed error instead of decoding leftover chunk bytes
    /// as a response.
    poisoned: Option<String>,
}

/// The historical Unix-socket client, now just the UDS instantiation of
/// [`RemoteClient`]. `ServeClient::connect(path)` keeps working.
pub type ServeClient = RemoteClient<UdsTransport>;

impl<T: Transport> RemoteClient<T> {
    /// Connect (and authenticate, where `transport` requires it). The
    /// connection carries [`IO_TIMEOUT`] in both directions.
    pub fn open(transport: T) -> Result<RemoteClient<T>> {
        let conn = transport.connect()?;
        // Best-effort: a transport that cannot set timeouts still works,
        // it just hangs as long as the OS lets it.
        let _ = conn.set_timeouts(Some(IO_TIMEOUT), Some(IO_TIMEOUT));
        Ok(RemoteClient {
            reader: BufReader::new(conn.try_clone()?),
            writer: BufWriter::new(conn),
            transport,
            poisoned: None,
        })
    }

    /// Tear down the current connection and dial a fresh one through the
    /// same transport (re-authenticating where required), with bounded
    /// exponential backoff. Clears stream poisoning — a fresh connection
    /// has no leftover frames. The idempotent methods call this
    /// automatically after a transport failure; it is public so callers
    /// holding a poisoned client can recover by hand too.
    pub fn reconnect(&mut self) -> Result<()> {
        let mut backoff = RECONNECT_BACKOFF;
        let mut last = None;
        for attempt in 0..RECONNECT_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff *= 4;
            }
            match self.transport.connect() {
                Ok(conn) => {
                    let _ = conn.set_timeouts(Some(IO_TIMEOUT), Some(IO_TIMEOUT));
                    self.reader = BufReader::new(conn.try_clone()?);
                    self.writer = BufWriter::new(conn);
                    self.poisoned = None;
                    crate::obs::metrics::registry().client_reconnects.inc();
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            crate::error::UniGpsError::ipc(format!(
                "reconnect to {} failed",
                self.transport.describe()
            ))
        }))
    }

    /// The endpoint this client talks to.
    pub fn endpoint(&self) -> String {
        self.transport.describe()
    }

    fn check_sync(&self) -> Result<()> {
        match &self.poisoned {
            Some(why) => Err(crate::error::UniGpsError::ipc(format!(
                "connection to {} desynchronized by an earlier result-stream \
                 failure ({why}); reconnect",
                self.transport.describe()
            ))),
            None => Ok(()),
        }
    }

    fn call(&mut self, m: u32, payload: &[u8]) -> Result<Vec<u8>> {
        self.check_sync()?;
        let (st, resp) =
            call_limited(&mut self.reader, &mut self.writer, m, payload, MAX_FRAME_LEN)?;
        if st == status::OK {
            Ok(resp)
        } else {
            Err(decode_error(&resp))
        }
    }

    /// [`RemoteClient::call`] for **idempotent** methods only (status,
    /// wait, result, stats, cancel): a transport-level failure — the
    /// connection dropped or timed out before a coherent reply — triggers
    /// one [`RemoteClient::reconnect`] and one resend. Typed server ERR
    /// frames are *not* retried (the server answered; the answer stands),
    /// and `submit`/`submit_plan` never come through here — blind
    /// resubmission could run a non-idempotent job twice
    /// ([`Client::submit_with_retry`] stays the explicit opt-in, and only
    /// for typed backpressure rejections).
    fn call_idempotent(&mut self, m: u32, payload: &[u8]) -> Result<Vec<u8>> {
        if self.poisoned.is_some() {
            self.reconnect()?;
        }
        match self.call(m, payload) {
            Err(crate::error::UniGpsError::Io(_)) => {
                self.reconnect()?;
                if let Some(replays) = crate::obs::metrics::replay_counter_for(m) {
                    replays.inc();
                }
                self.call(m, payload)
            }
            other => other,
        }
    }

    /// One `RESULT` round trip (see [`Client::result`] for the retry
    /// wrapper): request, then either a typed first-frame ERR or a
    /// chunked stream reassembled bit-exact. Mid-stream failures poison
    /// the connection.
    fn result_once(&mut self, id: JobId) -> Result<Arc<RunResult>> {
        self.check_sync()?;
        let mut req = Vec::new();
        put_u64(&mut req, id);
        crate::ipc::socket_rpc::write_frame(&mut self.writer, method::RESULT, &req)?;
        let (head, payload) = crate::ipc::socket_rpc::read_frame(&mut self.reader)?;
        match head {
            reply::ERR => Err(decode_error(&payload)),
            reply::RESULT_BEGIN => match read_result_stream_body(&mut self.reader, &payload) {
                Ok(table) => Ok(Arc::new(decode_result(&table)?)),
                Err(e) => {
                    self.poisoned = Some(e.message());
                    Err(e)
                }
            },
            other => {
                let e = crate::error::UniGpsError::ipc(format!(
                    "expected RESULT_BEGIN or ERR, got head {other}"
                ));
                self.poisoned = Some(e.message());
                Err(e)
            }
        }
    }
}

impl RemoteClient<UdsTransport> {
    /// Connect to a server's Unix socket (retrying briefly while it
    /// starts).
    pub fn connect(path: &Path) -> Result<ServeClient> {
        RemoteClient::open(UdsTransport::new(path))
    }
}

impl RemoteClient<TcpTransport> {
    /// Connect to a server's TCP listener at `addr` (`host:port`),
    /// authenticating with the preshared `token`. A bad token is the
    /// typed [`UniGpsError::Auth`](crate::error::UniGpsError::Auth) the
    /// server rejected the handshake with — no job is ever admitted from
    /// an unauthenticated connection.
    pub fn connect_tcp(addr: &str, token: &str) -> Result<RemoteClient<TcpTransport>> {
        RemoteClient::open(TcpTransport::new(addr, token))
    }
}

impl<T: Transport> Client for RemoteClient<T> {
    fn submit(&mut self, spec: &str) -> Result<JobId> {
        let resp = self.call(method::SUBMIT, spec.as_bytes())?;
        let mut pos = 0;
        get_u64(&resp, &mut pos)
    }

    fn submit_plan(&mut self, plan: &Plan) -> Result<JobId> {
        let resp = self.call(method::SUBMIT_PLAN, &encode_plan(plan))?;
        let mut pos = 0;
        get_u64(&resp, &mut pos)
    }

    fn status(&mut self, id: JobId) -> Result<JobStatus> {
        let mut req = Vec::new();
        put_u64(&mut req, id);
        JobStatus::decode(&self.call_idempotent(method::STATUS, &req)?)
    }

    /// Long-poll the server until the job is terminal: each round trip is
    /// a `WAIT` frame carrying the id and a deadline slice; the server
    /// parks on its scheduler's completion condvar and answers with the
    /// job's status — terminal, or still-pending once the slice expires.
    fn wait(&mut self, id: JobId, timeout: Duration) -> Result<Arc<RunResult>> {
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            let remaining = deadline.saturating_duration_since(now);
            let slice = remaining.min(WAIT_SLICE);
            let mut req = Vec::new();
            put_u64(&mut req, id);
            put_u64(&mut req, slice.as_millis() as u64);
            let st = JobStatus::decode(&self.call_idempotent(method::WAIT, &req)?)?;
            if st.state.is_terminal() {
                return self.result(id);
            }
            if Instant::now() >= deadline {
                return Err(wait_timeout_error(id, timeout, st.state.name()));
            }
        }
    }

    /// Fetch a finished job's result table as a chunked stream,
    /// reassembled bit-exact (length, chunk count and checksum verified).
    /// A clean first-frame ERR (job failed, unknown id, table over the
    /// stream cap) leaves the connection usable and is not retried. A
    /// failure *inside* the stream poisons the connection — leftover
    /// chunk frames would otherwise be misread as the next call's
    /// response — and, `RESULT` being idempotent, the client reconnects
    /// and retries the fetch once before surfacing the error.
    fn result(&mut self, id: JobId) -> Result<Arc<RunResult>> {
        if self.poisoned.is_some() {
            self.reconnect()?;
        }
        match self.result_once(id) {
            Err(e)
                if self.poisoned.is_some() || matches!(e, crate::error::UniGpsError::Io(_)) =>
            {
                self.reconnect()?;
                if let Some(replays) = crate::obs::metrics::replay_counter_for(method::RESULT) {
                    replays.inc();
                }
                self.result_once(id)
            }
            other => other,
        }
    }

    fn cancel(&mut self, id: JobId) -> Result<JobStatus> {
        let mut req = Vec::new();
        put_u64(&mut req, id);
        JobStatus::decode(&self.call_idempotent(method::CANCEL, &req)?)
    }

    /// Apply a delta batch over one `INGEST` frame. Deliberately plain
    /// [`RemoteClient::call`], never `call_idempotent`: ingestion
    /// advances the dataset's generation, so a blind resend after a
    /// transport failure could apply the batch twice (the second apply
    /// fails its add-present/remove-absent validation, but the caller
    /// should see the transport error, not a misleading Config one).
    fn ingest(&mut self, batch: &str) -> Result<crate::delta::IngestReceipt> {
        crate::delta::IngestReceipt::decode(&self.call(method::INGEST, batch.as_bytes())?)
    }

    fn stats(&mut self) -> Result<ServeStats> {
        ServeStats::decode(&self.call_idempotent(method::STATS, &[])?)
    }

    /// Fetch the server's process-wide metrics snapshot (one `METRICS`
    /// frame; idempotent, so a transport failure triggers one
    /// reconnect-and-resend like the other read-only methods).
    fn metrics(&mut self) -> Result<crate::obs::metrics::MetricsSnapshot> {
        crate::obs::metrics::MetricsSnapshot::decode(
            &self.call_idempotent(method::METRICS, &[])?,
        )
    }

    fn shutdown(&mut self) -> Result<()> {
        self.call(method::SHUTDOWN, &[])?;
        Ok(())
    }
}

impl<T: Transport> std::fmt::Debug for RemoteClient<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemoteClient({})", self.transport.describe())
    }
}

// Back-compat sugar: `submit_with_retry` predates the trait and keeps an
// inherent alias so that one call compiles without a trait import. The
// rest of the old inherent surface (submit/status/wait/result/stats/
// shutdown) deliberately moved to `Client` — callers import the trait
// and work against any implementation.
impl<T: Transport> RemoteClient<T> {
    /// Inherent alias for [`Client::submit_with_retry`].
    pub fn submit_with_retry(&mut self, spec: &str, timeout: Duration) -> Result<JobId> {
        Client::submit_with_retry(self, spec, timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::UniGpsError;

    // RemoteClient's wire behavior is covered by rust/tests/
    // client_transports.rs and serve_integration.rs (it needs a live
    // server); here we only pin the pieces that are pure.

    #[test]
    fn wait_slice_fits_under_the_server_cap() {
        assert!(WAIT_SLICE.as_millis() as u64 <= crate::serve::server::MAX_WAIT_SLICE_MS);
    }

    #[test]
    fn io_timeout_outlasts_a_wait_slice() {
        // A healthy WAIT round trip keeps the socket quiet for a full
        // slice; the client must not cut the connection under it.
        assert!(IO_TIMEOUT > WAIT_SLICE);
        // Same invariant server-side: the default per-connection read
        // timeout must outlast the server's own WAIT park cap, or idle
        // waiting clients would be dropped mid-long-poll.
        let cfg = crate::serve::ServeConfig::new("/tmp/x.sock");
        let read = cfg.read_timeout.expect("server read timeout defaults on");
        assert!(read.as_millis() as u64 > crate::serve::server::MAX_WAIT_SLICE_MS);
        assert!(cfg.write_timeout.is_some(), "write timeout defaults on");
    }

    #[test]
    fn timeout_error_names_the_state() {
        let e = wait_timeout_error(7, Duration::from_secs(3), "queued");
        assert!(matches!(e, UniGpsError::Serve(_)), "{e:?}");
        assert!(e.to_string().contains("job 7"), "{e}");
        assert!(e.to_string().contains("queued"), "{e}");
    }
}
