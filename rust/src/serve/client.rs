//! The wire [`Client`]: [`RemoteClient`]`<T>` over any [`Transport`].
//!
//! One synchronous request at a time per client (open one client per
//! thread; the server handles connections concurrently). Speaks the
//! strict untrusted framing (`MAX_FRAME_LEN` enforced on read and write)
//! on whatever byte stream the transport produced — the Unix-domain
//! socket, or TCP after the transport's preshared-token HELLO handshake
//! — and decodes kind-tagged ERR frames back into typed
//! [`UniGpsError`](crate::error::UniGpsError) values.
//!
//! Two protocol features keep the client thin:
//!
//! * **`WAIT` long-poll** — [`Client::wait`] parks on the server (which
//!   blocks on the scheduler's completion condvar) instead of hammering
//!   `STATUS` in a 2 → 128 ms backoff loop like the old `ServeClient`
//!   did; one round trip per [`WAIT_SLICE`] of waiting, not ~500 status
//!   calls per second per waiter.
//! * **Chunked results** — [`Client::result`] reads the
//!   `RESULT_BEGIN / RESULT_CHUNK / RESULT_END` stream
//!   ([`read_result_stream_body`]), so result tables of any size up to
//!   the stream cap (full-scale `uk` columns included) arrive bit-exact;
//!   the single-frame ceiling and its typed-ERR consolation are gone. A
//!   failure *inside* a stream (cap, count, checksum) poisons the
//!   connection — later calls fail fast with a typed error instead of
//!   misreading leftover chunk frames as responses.

use crate::client::{wait_timeout_error, Client};
use crate::engine::RunResult;
use crate::error::Result;
use crate::ipc::protocol::{get_u64, put_u64, status};
use crate::ipc::socket_rpc::{call_limited, MAX_FRAME_LEN};
use crate::plan::wire::encode_plan;
use crate::plan::Plan;
use crate::serve::jobs::{decode_result, JobId, JobStatus};
use crate::serve::method;
use crate::serve::server::ServeStats;
use crate::serve::transport::{
    decode_error, read_result_stream_body, reply, Conn, TcpTransport, Transport, UdsTransport,
};
use std::io::{BufReader, BufWriter};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Longest wait a single `WAIT` round trip asks the server for. The
/// server clamps harder (its own cap); the client slices its deadline so
/// a dead server is noticed within one slice, not one full timeout.
pub const WAIT_SLICE: Duration = Duration::from_secs(10);

/// Client for a [`Server`](crate::serve::Server) over a connection
/// transport `T`. See the [module docs](self) for the protocol surface.
pub struct RemoteClient<T: Transport> {
    transport: T,
    reader: BufReader<Conn>,
    writer: BufWriter<Conn>,
    /// Set when a result stream failed mid-reassembly (cap, count or
    /// checksum violation): unread chunk frames may still be buffered,
    /// so the request/response pairing is gone. Every later call fails
    /// fast with a typed error instead of decoding leftover chunk bytes
    /// as a response.
    poisoned: Option<String>,
}

/// The historical Unix-socket client, now just the UDS instantiation of
/// [`RemoteClient`]. `ServeClient::connect(path)` keeps working.
pub type ServeClient = RemoteClient<UdsTransport>;

impl<T: Transport> RemoteClient<T> {
    /// Connect (and authenticate, where `transport` requires it).
    pub fn open(transport: T) -> Result<RemoteClient<T>> {
        let conn = transport.connect()?;
        Ok(RemoteClient {
            reader: BufReader::new(conn.try_clone()?),
            writer: BufWriter::new(conn),
            transport,
            poisoned: None,
        })
    }

    /// The endpoint this client talks to.
    pub fn endpoint(&self) -> String {
        self.transport.describe()
    }

    fn check_sync(&self) -> Result<()> {
        match &self.poisoned {
            Some(why) => Err(crate::error::UniGpsError::ipc(format!(
                "connection to {} desynchronized by an earlier result-stream \
                 failure ({why}); reconnect",
                self.transport.describe()
            ))),
            None => Ok(()),
        }
    }

    fn call(&mut self, m: u32, payload: &[u8]) -> Result<Vec<u8>> {
        self.check_sync()?;
        let (st, resp) =
            call_limited(&mut self.reader, &mut self.writer, m, payload, MAX_FRAME_LEN)?;
        if st == status::OK {
            Ok(resp)
        } else {
            Err(decode_error(&resp))
        }
    }
}

impl RemoteClient<UdsTransport> {
    /// Connect to a server's Unix socket (retrying briefly while it
    /// starts).
    pub fn connect(path: &Path) -> Result<ServeClient> {
        RemoteClient::open(UdsTransport::new(path))
    }
}

impl RemoteClient<TcpTransport> {
    /// Connect to a server's TCP listener at `addr` (`host:port`),
    /// authenticating with the preshared `token`. A bad token is the
    /// typed [`UniGpsError::Auth`](crate::error::UniGpsError::Auth) the
    /// server rejected the handshake with — no job is ever admitted from
    /// an unauthenticated connection.
    pub fn connect_tcp(addr: &str, token: &str) -> Result<RemoteClient<TcpTransport>> {
        RemoteClient::open(TcpTransport::new(addr, token))
    }
}

impl<T: Transport> Client for RemoteClient<T> {
    fn submit(&mut self, spec: &str) -> Result<JobId> {
        let resp = self.call(method::SUBMIT, spec.as_bytes())?;
        let mut pos = 0;
        get_u64(&resp, &mut pos)
    }

    fn submit_plan(&mut self, plan: &Plan) -> Result<JobId> {
        let resp = self.call(method::SUBMIT_PLAN, &encode_plan(plan))?;
        let mut pos = 0;
        get_u64(&resp, &mut pos)
    }

    fn status(&mut self, id: JobId) -> Result<JobStatus> {
        let mut req = Vec::new();
        put_u64(&mut req, id);
        JobStatus::decode(&self.call(method::STATUS, &req)?)
    }

    /// Long-poll the server until the job is terminal: each round trip is
    /// a `WAIT` frame carrying the id and a deadline slice; the server
    /// parks on its scheduler's completion condvar and answers with the
    /// job's status — terminal, or still-pending once the slice expires.
    fn wait(&mut self, id: JobId, timeout: Duration) -> Result<Arc<RunResult>> {
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            let remaining = deadline.saturating_duration_since(now);
            let slice = remaining.min(WAIT_SLICE);
            let mut req = Vec::new();
            put_u64(&mut req, id);
            put_u64(&mut req, slice.as_millis() as u64);
            let st = JobStatus::decode(&self.call(method::WAIT, &req)?)?;
            if st.state.is_terminal() {
                return self.result(id);
            }
            if Instant::now() >= deadline {
                return Err(wait_timeout_error(id, timeout, st.state.name()));
            }
        }
    }

    /// Fetch a finished job's result table as a chunked stream,
    /// reassembled bit-exact (length, chunk count and checksum verified).
    /// A clean first-frame ERR (job failed, unknown id, table over the
    /// stream cap) leaves the connection usable; a failure *inside* the
    /// stream poisons this client — leftover chunk frames would otherwise
    /// be misread as the next call's response.
    fn result(&mut self, id: JobId) -> Result<Arc<RunResult>> {
        self.check_sync()?;
        let mut req = Vec::new();
        put_u64(&mut req, id);
        crate::ipc::socket_rpc::write_frame(&mut self.writer, method::RESULT, &req)?;
        let (head, payload) = crate::ipc::socket_rpc::read_frame(&mut self.reader)?;
        match head {
            reply::ERR => Err(decode_error(&payload)),
            reply::RESULT_BEGIN => match read_result_stream_body(&mut self.reader, &payload) {
                Ok(table) => Ok(Arc::new(decode_result(&table)?)),
                Err(e) => {
                    self.poisoned = Some(e.message());
                    Err(e)
                }
            },
            other => {
                let e = crate::error::UniGpsError::ipc(format!(
                    "expected RESULT_BEGIN or ERR, got head {other}"
                ));
                self.poisoned = Some(e.message());
                Err(e)
            }
        }
    }

    fn stats(&mut self) -> Result<ServeStats> {
        ServeStats::decode(&self.call(method::STATS, &[])?)
    }

    fn shutdown(&mut self) -> Result<()> {
        self.call(method::SHUTDOWN, &[])?;
        Ok(())
    }
}

impl<T: Transport> std::fmt::Debug for RemoteClient<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemoteClient({})", self.transport.describe())
    }
}

// Back-compat sugar: `submit_with_retry` predates the trait and keeps an
// inherent alias so that one call compiles without a trait import. The
// rest of the old inherent surface (submit/status/wait/result/stats/
// shutdown) deliberately moved to `Client` — callers import the trait
// and work against any implementation.
impl<T: Transport> RemoteClient<T> {
    /// Inherent alias for [`Client::submit_with_retry`].
    pub fn submit_with_retry(&mut self, spec: &str, timeout: Duration) -> Result<JobId> {
        Client::submit_with_retry(self, spec, timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::UniGpsError;

    // RemoteClient's wire behavior is covered by rust/tests/
    // client_transports.rs and serve_integration.rs (it needs a live
    // server); here we only pin the pieces that are pure.

    #[test]
    fn wait_slice_fits_under_the_server_cap() {
        assert!(WAIT_SLICE.as_millis() as u64 <= crate::serve::server::MAX_WAIT_SLICE_MS);
    }

    #[test]
    fn timeout_error_names_the_state() {
        let e = wait_timeout_error(7, Duration::from_secs(3), "queued");
        assert!(matches!(e, UniGpsError::Serve(_)), "{e:?}");
        assert!(e.to_string().contains("job 7"), "{e}");
        assert!(e.to_string().contains("queued"), "{e}");
    }
}
