//! Job specs, the job state machine and the serving wire codecs.
//!
//! A job **is a plan**: [`JobSpec`] carries a [`Plan`] (source + steps +
//! post-ops) plus the session resolved from the plan's defaults over the
//! server's session via [`Session::overlay_config`]. Two spec texts are
//! accepted:
//!
//! * the **sectioned plan format** ([`Plan::parse_text`], documented in
//!   `docs/plans.md`) — multi-stage pipelines with transforms, per-stage
//!   `engine=`/options, and result post-ops;
//! * the historical **flat single-op form** — plain `key = value` lines
//!   with `algo`, operator parameters and session options — which lowers
//!   to a one-stage plan, so old clients keep working and land on the
//!   same executor. Flat keys:
//!
//! | key | meaning | default |
//! |-----|---------|---------|
//! | `algo` | operator: `pagerank`, `sssp`, `cc`, `bfs`, `degrees`, `lpa`, `kcore`, `triangles` | `pagerank` |
//! | `custom` | registered custom VCProg instead of `algo` | — |
//! | `iterations` | PageRank / LPA rounds | 20 / 10 |
//! | `root` | SSSP / BFS / custom source vertex | 0 |
//! | `k` | k-core threshold | 3 |
//! | `dataset` + `scale` | Table II analog by key at `1/scale` | — |
//! | `kind` + `vertices` + `edges` + `seed` | seeded synthetic generator | — |
//! | `graph` | load from a file path (format by extension) | — |
//! | `delay_ms` | synthetic service time before execution (test/bench aid, ≤ 60 s) | 0 |
//! | `deadline_ms` | cancel the job if not terminal this long after admission (0 = none, ≤ 1 h) | 0 |
//! | `generation` | dataset generation to run on: `latest` or a fixed epoch number (`docs/evolving.md`) | `latest` |
//!
//! Exactly one graph source (`dataset`, `graph`, or synthetic) must be
//! given — in the flat keys or the plan's top section. Plans can also be
//! submitted pre-encoded ([`crate::plan::wire`]) via the `SUBMIT_PLAN`
//! method; both paths run [`JobSpec::from_plan`] so the allocation caps
//! hold regardless of transport. Statuses and result tables cross the
//! wire with the length-checked [`crate::ipc::protocol`] primitives.
//!
//! [`Session::overlay_config`]: crate::session::Session::overlay_config

use crate::config::Config;
use crate::engine::{EngineKind, RunResult};
use crate::error::{Result, UniGpsError};
use crate::ipc::protocol::{get_bytes, get_u32, get_u64, put_bytes, put_u32, put_u64};
use crate::plan::text::{is_plan_text, stage_from_config};
use crate::plan::{Plan, PlanStep};
use crate::session::Session;
use crate::vcprog::Column;

// Compatibility re-exports: these lived here before the plan IR became
// the shared surface.
pub use crate::plan::source::{
    DatasetRef, MAX_GRAPH_FILE_BYTES, MAX_SYNTH_EDGES, MAX_SYNTH_VERTICES,
};

/// Server-assigned job identifier (monotone per server instance).
pub type JobId = u64;

/// Largest `delay_ms` a job spec may request (60 s) — the field exists for
/// tests/benches, and an uncapped value would let one hostile spec pin a
/// scheduler slot indefinitely.
pub const MAX_DELAY_MS: u64 = 60_000;

/// Largest `deadline_ms` a job spec may request (1 h). The deadline clock
/// starts at admission and covers queue time; a value past any sane job
/// length is indistinguishable from "no deadline", so it is capped rather
/// than honoured literally.
pub const MAX_DEADLINE_MS: u64 = 3_600_000;

/// A parsed, validated job: the plan to execute, and the session resolved
/// from the plan defaults over the server session.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Engine + run options resolved from the spec over the server session.
    pub session: Session,
    /// The plan this job executes (source always present).
    pub plan: Plan,
    /// Synthetic pre-execution service time in milliseconds (test/bench
    /// aid; 0 in normal operation).
    pub delay_ms: u64,
    /// Milliseconds after admission at which the scheduler's watchdog
    /// cancels the job if it has not reached a terminal state (0 = no
    /// deadline).
    pub deadline_ms: u64,
}

impl JobSpec {
    /// Parse spec text, layering it over `base` (the server's session).
    /// Sectioned text parses as a full plan; flat `key = value` text
    /// lowers to a one-stage plan. All failures are typed
    /// [`UniGpsError::Config`] values.
    pub fn parse(text: &str, base: &Session) -> Result<JobSpec> {
        if is_plan_text(text) {
            return JobSpec::from_plan(Plan::parse_text(text)?, base);
        }
        let cfg = Config::parse(text)?;
        let source = DatasetRef::from_config(&cfg)?.ok_or_else(no_source)?;
        let stage = stage_from_config(&cfg, true)?;
        let mut plan = Plan::new().source(source);
        plan.steps.push(PlanStep::Run(stage));
        if let Some(d) = cfg.get("delay_ms") {
            plan.defaults.set("delay_ms", d);
        }
        if let Some(d) = cfg.get("deadline_ms") {
            plan.defaults.set("deadline_ms", d);
        }
        if let Some(g) = cfg.get("generation") {
            plan.defaults.set("generation", g);
        }
        JobSpec::from_plan_with_session(plan, base.overlay_config(&cfg)?)
    }

    /// Validate a decoded or constructed plan into a job over `base`:
    /// source required and capped, structure validated, `delay_ms`
    /// (read from the plan defaults) capped. The wire `SUBMIT_PLAN` path
    /// lands here, so forged plans meet the same limits as parsed text.
    pub fn from_plan(plan: Plan, base: &Session) -> Result<JobSpec> {
        let session = base.overlay_config(&plan.defaults)?;
        JobSpec::from_plan_with_session(plan, session)
    }

    fn from_plan_with_session(plan: Plan, session: Session) -> Result<JobSpec> {
        let source = plan.source.as_ref().ok_or_else(no_source)?;
        source.check_caps()?;
        plan.validate()?;
        // Stage overrides must resolve — catch a bad per-stage engine at
        // admission instead of inside a scheduler slot.
        for stage in plan.stages() {
            session.overlay_config(&stage.overrides)?;
        }
        let delay_ms = plan.defaults.get_usize("delay_ms", 0)? as u64;
        if delay_ms > MAX_DELAY_MS {
            return Err(UniGpsError::Config(format!(
                "delay_ms must be <= {MAX_DELAY_MS}, got {delay_ms}"
            )));
        }
        let deadline_ms = plan.defaults.get_usize("deadline_ms", 0)? as u64;
        if deadline_ms > MAX_DEADLINE_MS {
            return Err(UniGpsError::Config(format!(
                "deadline_ms must be <= {MAX_DEADLINE_MS}, got {deadline_ms}"
            )));
        }
        // Generation pin: `latest` (the default) or a fixed epoch number.
        // Whether the epoch exists is checked at run start — an admitted
        // pin can reference an epoch ingested between submit and run.
        if let Some(g) = plan.defaults.get("generation") {
            if g != "latest" && g.trim().parse::<u64>().is_err() {
                return Err(UniGpsError::Config(format!(
                    "generation must be `latest` or an epoch number, got `{g}`"
                )));
            }
        }
        Ok(JobSpec {
            session,
            plan,
            delay_ms,
            deadline_ms,
        })
    }

    /// The engine this job's stages default to.
    pub fn engine(&self) -> EngineKind {
        self.session.default_engine()
    }

    /// The job's graph source (always present after validation).
    pub fn dataset(&self) -> &DatasetRef {
        // lint: allow-panic: every JobSpec constructor rejects a source-less
        // plan (`no_source`) at admission, so this is invariant-checked —
        // never reachable from a client frame.
        self.plan.source.as_ref().expect("validated: source present")
    }
}

fn no_source() -> UniGpsError {
    UniGpsError::Config(
        "job spec needs a graph source: dataset = <key>, graph = <path>, \
         or kind/vertices/edges/seed"
            .into(),
    )
}

/// Job state machine: `Queued → Running → Done | Failed | Cancelled`
/// (queued jobs can also go straight to `Cancelled`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Admitted, waiting in the FIFO queue.
    Queued,
    /// Executing in a scheduler slot.
    Running,
    /// Finished; the result table is available.
    Done,
    /// Finished with a typed error (see [`JobStatus::error`]).
    Failed,
    /// Cooperatively cancelled — by `Client::cancel`, the deadline
    /// watchdog, or the scheduler's drain grace period. Terminal; the
    /// cancellation reason travels in [`JobStatus::error`].
    Cancelled,
}

impl JobState {
    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// True once the job can make no further progress.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }

    fn code(self) -> u32 {
        match self {
            JobState::Queued => 0,
            JobState::Running => 1,
            JobState::Done => 2,
            JobState::Failed => 3,
            JobState::Cancelled => 4,
        }
    }

    fn from_code(c: u32) -> Result<JobState> {
        Ok(match c {
            0 => JobState::Queued,
            1 => JobState::Running,
            2 => JobState::Done,
            3 => JobState::Failed,
            4 => JobState::Cancelled,
            other => return Err(UniGpsError::Ipc(format!("bad job-state code {other}"))),
        })
    }
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A job's externally visible status.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobStatus {
    /// Job id.
    pub id: JobId,
    /// Current state.
    pub state: JobState,
    /// Failure message when `state == Failed`.
    pub error: Option<String>,
    /// Rendered trace profile ([`crate::obs::trace::render`]), attached
    /// once the job is terminal. Travels as a trailing optional wire
    /// field: old peers that stop decoding after `error` stay compatible.
    pub profile: Option<String>,
}

impl JobStatus {
    /// A status with no error and no profile attached.
    pub fn new(id: JobId, state: JobState) -> JobStatus {
        JobStatus { id, state, error: None, profile: None }
    }

    /// Encode for the wire.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u64(&mut out, self.id);
        put_u32(&mut out, self.state.code());
        put_bytes(&mut out, self.error.as_deref().unwrap_or("").as_bytes());
        put_bytes(&mut out, self.profile.as_deref().unwrap_or("").as_bytes());
        out
    }

    /// Decode from the wire. The trailing profile field is optional: a
    /// frame ending after `error` (an older encoder) decodes with
    /// `profile: None`.
    pub fn decode(buf: &[u8]) -> Result<JobStatus> {
        let mut pos = 0;
        let id = get_u64(buf, &mut pos)?;
        let state = JobState::from_code(get_u32(buf, &mut pos)?)?;
        let err = String::from_utf8_lossy(get_bytes(buf, &mut pos)?).into_owned();
        let profile = if pos < buf.len() {
            let p = String::from_utf8_lossy(get_bytes(buf, &mut pos)?).into_owned();
            if p.is_empty() { None } else { Some(p) }
        } else {
            None
        };
        Ok(JobStatus {
            id,
            state,
            error: if err.is_empty() { None } else { Some(err) },
            profile,
        })
    }
}

const COL_I64: u32 = 0;
const COL_F64: u32 = 1;

/// Encode a result table + the cross-process subset of its metrics.
/// Values travel as raw little-endian 64-bit words, so a decoded column is
/// bit-identical to the engine's output (including float payload bits).
pub fn encode_result(r: &RunResult) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, r.metrics.supersteps);
    put_u32(&mut out, r.metrics.workers as u32);
    put_u32(&mut out, u32::from(r.metrics.converged));
    put_u64(&mut out, r.metrics.total_messages);
    put_u64(&mut out, r.metrics.total_message_bytes);
    put_u64(&mut out, r.metrics.udf_calls);
    put_u64(&mut out, r.metrics.elapsed.as_micros() as u64);
    put_u32(&mut out, r.columns.len() as u32);
    for (name, col) in &r.columns {
        put_bytes(&mut out, name.as_bytes());
        match col {
            Column::I64(v) => {
                put_u32(&mut out, COL_I64);
                put_u64(&mut out, v.len() as u64);
                for x in v {
                    put_u64(&mut out, *x as u64);
                }
            }
            Column::F64(v) => {
                put_u32(&mut out, COL_F64);
                put_u64(&mut out, v.len() as u64);
                for x in v {
                    put_u64(&mut out, x.to_bits());
                }
            }
        }
    }
    out
}

/// Decode a result table. Per-step metrics and worker busy times do not
/// cross the wire; the scalar metrics (supersteps, messages, convergence,
/// elapsed) do.
pub fn decode_result(buf: &[u8]) -> Result<RunResult> {
    let mut pos = 0;
    // Field expressions evaluate in literal order, which matches the
    // encode order above.
    let metrics = crate::distributed::metrics::RunMetrics {
        supersteps: get_u32(buf, &mut pos)?,
        workers: get_u32(buf, &mut pos)? as usize,
        converged: get_u32(buf, &mut pos)? != 0,
        total_messages: get_u64(buf, &mut pos)?,
        total_message_bytes: get_u64(buf, &mut pos)?,
        udf_calls: get_u64(buf, &mut pos)?,
        elapsed: std::time::Duration::from_micros(get_u64(buf, &mut pos)?),
        ..Default::default()
    };
    let ncols = get_u32(buf, &mut pos)? as usize;
    let mut columns = Vec::with_capacity(ncols.min(64));
    for _ in 0..ncols {
        let name = String::from_utf8_lossy(get_bytes(buf, &mut pos)?).into_owned();
        let tag = get_u32(buf, &mut pos)?;
        let len = get_u64(buf, &mut pos)? as usize;
        // Each value is 8 wire bytes; an impossible length is a protocol
        // violation, not an allocation request.
        if buf.len().saturating_sub(pos) < len.saturating_mul(8) {
            return Err(UniGpsError::Ipc(format!(
                "result column '{name}' declares {len} values but the frame is too short"
            )));
        }
        let col = match tag {
            COL_I64 => {
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(get_u64(buf, &mut pos)? as i64);
                }
                Column::I64(v)
            }
            COL_F64 => {
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(f64::from_bits(get_u64(buf, &mut pos)?));
                }
                Column::F64(v)
            }
            other => return Err(UniGpsError::Ipc(format!("bad column tag {other}"))),
        };
        columns.push((name, col));
    }
    Ok(RunResult { columns, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::metrics::RunMetrics;
    use crate::graph::partition::PartitionStrategy;
    use crate::operators::Operator;
    use crate::plan::StageOp;
    use std::path::PathBuf;

    fn base() -> Session {
        Session::builder().workers(3).build()
    }

    #[test]
    fn flat_spec_lowers_to_a_one_stage_plan() {
        let spec = JobSpec::parse(
            "algo = sssp\nroot = 5\nengine = gemini\ndataset = lj\nscale = 2048\npartition = range",
            &base(),
        )
        .unwrap();
        assert_eq!(spec.engine(), EngineKind::PushPull);
        let stages = spec.plan.stages();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].op, StageOp::Op(Operator::Sssp { root: 5 }));
        assert_eq!(stages[0].overrides.get("engine"), Some("gemini"));
        assert_eq!(
            spec.dataset(),
            &DatasetRef::Named {
                key: "lj".into(),
                scale: 2048
            }
        );
        assert_eq!(spec.session.options().partition, PartitionStrategy::Range);
        assert_eq!(spec.session.options().workers, 3, "base session default kept");
        assert_eq!(spec.delay_ms, 0);
    }

    #[test]
    fn spec_synthetic_and_file_sources() {
        let spec = JobSpec::parse("vertices = 256\nedges = 1024\nseed = 9", &base()).unwrap();
        assert_eq!(
            spec.dataset(),
            &DatasetRef::Synthetic {
                kind: "rmat".into(),
                vertices: 256,
                edges: 1024,
                seed: 9
            }
        );
        let spec = JobSpec::parse("graph = /data/g.bin\nalgo = cc", &base()).unwrap();
        assert_eq!(
            spec.dataset(),
            &DatasetRef::File {
                path: PathBuf::from("/data/g.bin"),
                store: crate::store::StoreMode::Heap
            }
        );
        assert_eq!(
            spec.plan.stages()[0].op,
            StageOp::Op(Operator::ConnectedComponents)
        );
    }

    #[test]
    fn sectioned_spec_parses_as_a_multi_stage_plan() {
        let text = "\
kind = rmat\nvertices = 128\nedges = 512\nseed = 1\ndelay_ms = 5\n\n\
[transform]\nop = symmetrize\n\n\
[stage]\nalgo = cc\n\n\
[stage]\nalgo = kcore\nk = 2\nengine = gas\n";
        let spec = JobSpec::parse(text, &base()).unwrap();
        assert_eq!(spec.plan.stages().len(), 2);
        assert_eq!(spec.delay_ms, 5);
        assert_eq!(spec.session.options().workers, 3, "base defaults kept");
    }

    #[test]
    fn spec_rejections_are_typed() {
        for bad in [
            "algo = dijkstra\ndataset = lj",       // unknown algo
            "algo = pagerank",                     // no graph source
            "dataset = lj\nengine = fortran",      // unknown engine
            "dataset = lj\npartition = voronoi",   // unknown partition
            "dataset = lj\nworkers = many",        // type error
            "not a key value line",                // malformed config
            "dataset = lj\nscale = 0",             // divide-by-zero scale
            "vertices = 0",                        // degenerate generator
            "vertices = 10000000000000000",        // allocation-bomb vertices
            "vertices = 64\nedges = 10000000000000000", // allocation-bomb edges
            "vertices = 64\ndelay_ms = 86400000",  // slot-pinning delay
            "vertices = 64\ndeadline_ms = 86400000", // over-cap deadline
            "[stage]\nalgo = cc",                  // plan without a source
            "dataset = lj\n[stage]\nalgo = cc\nengine = warp", // bad stage override
            "dataset = lj\ngeneration = newest",   // bad generation pin
        ] {
            let err = JobSpec::parse(bad, &base()).unwrap_err();
            assert!(matches!(err, UniGpsError::Config(_)), "{bad:?} -> {err:?}");
        }
    }

    #[test]
    fn from_plan_enforces_caps_on_wire_submitted_plans() {
        // A forged plan skips text parsing; caps must still hold.
        let plan = Plan::single(Operator::Degrees).source(DatasetRef::Synthetic {
            kind: "rmat".into(),
            vertices: usize::MAX,
            edges: 1,
            seed: 0,
        });
        let err = JobSpec::from_plan(plan, &base()).unwrap_err();
        assert!(matches!(err, UniGpsError::Config(_)), "{err:?}");
        // And delay_ms read from plan defaults is capped.
        let plan = Plan::single(Operator::Degrees)
            .source(DatasetRef::Named { key: "lj".into(), scale: 64 })
            .default_key("delay_ms", 86_400_000u64);
        assert!(JobSpec::from_plan(plan, &base()).is_err());
    }

    #[test]
    fn flat_and_sectioned_specs_lower_to_the_same_plan() {
        let flat = JobSpec::parse(
            "algo = sssp\nroot = 5\nengine = gas\nworkers = 2\nvertices = 64\nedges = 128\nseed = 3",
            &base(),
        )
        .unwrap();
        let sectioned = JobSpec::parse(
            "kind = rmat\nvertices = 64\nedges = 128\nseed = 3\n\n\
             [stage]\nalgo = sssp\nroot = 5\nengine = gas\nworkers = 2\n",
            &base(),
        )
        .unwrap();
        assert_eq!(flat.plan.steps, sectioned.plan.steps, "same lowered stages");
        assert_eq!(flat.plan.source, sectioned.plan.source);
    }

    #[test]
    fn status_roundtrip() {
        for status in [
            JobStatus::new(7, JobState::Queued),
            JobStatus::new(8, JobState::Running),
            JobStatus::new(u64::MAX, JobState::Done),
            JobStatus {
                id: 0,
                state: JobState::Failed,
                error: Some("engine error: boom".into()),
                profile: None,
            },
            JobStatus {
                id: 9,
                state: JobState::Cancelled,
                error: Some("deadline exceeded".into()),
                profile: None,
            },
            JobStatus {
                id: 10,
                state: JobState::Done,
                error: None,
                profile: Some("job 10 profile: total 1.0ms, 1 span(s)\n".into()),
            },
        ] {
            assert_eq!(JobStatus::decode(&status.encode()).unwrap(), status);
        }
        assert!(JobStatus::decode(&[1, 2, 3]).is_err());
    }

    #[test]
    fn status_decode_tolerates_frames_without_the_profile_field() {
        // An older encoder stops after `error`; the trailing profile is
        // optional on decode.
        let mut old = Vec::new();
        put_u64(&mut old, 42);
        put_u32(&mut old, 2); // Done
        put_bytes(&mut old, b"");
        let s = JobStatus::decode(&old).unwrap();
        assert_eq!(s, JobStatus::new(42, JobState::Done));
        assert_eq!(s.profile, None);
    }

    #[test]
    fn state_machine_terminality() {
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal());
        assert!(JobState::Failed.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
        assert_eq!(JobState::Running.to_string(), "running");
        assert_eq!(JobState::Cancelled.to_string(), "cancelled");
    }

    #[test]
    fn generation_pin_travels_in_plan_defaults() {
        let spec = JobSpec::parse(
            "vertices = 64\nedges = 128\nseed = 1\ngeneration = 2",
            &base(),
        )
        .unwrap();
        assert_eq!(spec.plan.defaults.get("generation"), Some("2"));
        let spec = JobSpec::parse(
            "vertices = 64\nedges = 128\nseed = 1\ngeneration = latest",
            &base(),
        )
        .unwrap();
        assert_eq!(spec.plan.defaults.get("generation"), Some("latest"));
        let spec = JobSpec::parse("vertices = 64\nedges = 128\nseed = 1", &base()).unwrap();
        assert_eq!(spec.plan.defaults.get("generation"), None, "latest by default");
    }

    #[test]
    fn deadline_ms_parses_and_caps() {
        let spec =
            JobSpec::parse("vertices = 64\nedges = 128\nseed = 1\ndeadline_ms = 500", &base())
                .unwrap();
        assert_eq!(spec.deadline_ms, 500);
        let spec = JobSpec::parse("vertices = 64\nedges = 128\nseed = 1", &base()).unwrap();
        assert_eq!(spec.deadline_ms, 0, "no deadline by default");
    }

    #[test]
    fn result_roundtrip_is_bit_identical() {
        let r = RunResult {
            columns: vec![
                ("rank".into(), Column::F64(vec![0.1, -0.0, f64::NAN, 3e300])),
                ("component".into(), Column::I64(vec![i64::MIN, -1, 0, i64::MAX])),
            ],
            metrics: RunMetrics {
                supersteps: 12,
                total_messages: 3456,
                total_message_bytes: 27648,
                elapsed: std::time::Duration::from_micros(98765),
                converged: true,
                steps: vec![],
                workers: 4,
                udf_calls: 999,
                worker_busy: vec![],
            },
        };
        let back = decode_result(&encode_result(&r)).unwrap();
        assert_eq!(back.columns.len(), 2);
        let (name, col) = &back.columns[0];
        assert_eq!(name, "rank");
        let f = col.as_f64().unwrap();
        let orig = r.columns[0].1.as_f64().unwrap();
        for (a, b) in f.iter().zip(orig) {
            assert_eq!(a.to_bits(), b.to_bits(), "float bits preserved (incl. NaN/-0.0)");
        }
        assert_eq!(back.columns[1].1.as_i64().unwrap(), &[i64::MIN, -1, 0, i64::MAX]);
        assert_eq!(back.metrics.supersteps, 12);
        assert_eq!(back.metrics.total_messages, 3456);
        assert_eq!(back.metrics.workers, 4);
        assert!(back.metrics.converged);
        assert_eq!(back.metrics.elapsed.as_micros(), 98765);
    }

    #[test]
    fn result_decode_rejects_corrupt_frames() {
        let r = RunResult {
            columns: vec![("x".into(), Column::I64(vec![1, 2, 3]))],
            metrics: RunMetrics::default(),
        };
        let good = encode_result(&r);
        // Truncations at every prefix must fail typed, never panic.
        for cut in 0..good.len() {
            assert!(decode_result(&good[..cut]).is_err(), "cut at {cut}");
        }
        // A forged huge column length is a protocol violation, not an
        // allocation request.
        let mut forged = Vec::new();
        for _ in 0..3 {
            put_u32(&mut forged, 0);
        }
        for _ in 0..4 {
            put_u64(&mut forged, 0);
        }
        put_u32(&mut forged, 1); // one column
        put_bytes(&mut forged, b"rank");
        put_u32(&mut forged, COL_F64);
        put_u64(&mut forged, u64::MAX); // absurd length
        let err = decode_result(&forged).unwrap_err();
        assert!(matches!(err, UniGpsError::Ipc(_)));
    }
}
