//! `unigps serve` — the resident graph-analytics job service.
//!
//! The paper's architecture (Fig 3) is a *session* object in front of a
//! pool of backend engines: analysts describe jobs (graph + program +
//! `engine=` parameter) and never touch distributed internals. The CLI
//! reproduced that API shape but not its economics — every `unigps run`
//! re-parsed flags, re-generated/loaded the graph, ran exactly one program
//! and exited, so a pipeline of short jobs paid the dominant load/partition
//! cost per job (the end-to-end-time observation of the Waterloo systems
//! comparison, arXiv 1806.08082) and shared nothing (the one-resident-graph
//! pipeline model GraphX argues for, arXiv 1402.2394).
//!
//! This module keeps the session resident and serves jobs behind the one
//! [`Client`](crate::client::Client) API — over a Unix-domain socket,
//! over authenticated TCP, or with no socket at all
//! ([`LocalClient`](crate::client::LocalClient) runs the same scheduler
//! and cache in process):
//!
//! * [`transport`] — the connection layer: the client-side
//!   [`Transport`](transport::Transport) trait ([`UdsTransport`] /
//!   [`TcpTransport`] with its mandatory preshared-token HELLO
//!   handshake), the server's [`Listener`](transport::Listener) /
//!   [`Conn`](transport::Conn) pair, the chunked
//!   `RESULT_BEGIN / RESULT_CHUNK / RESULT_END` result-stream codec
//!   that removed the single-frame result ceiling, and the kind-tagged
//!   ERR codec.
//! * [`server`] — the accept loops (one per bound listener) and frame
//!   dispatch: submit / status / wait / result / stats / shutdown over
//!   the length-prefixed [`crate::ipc::socket_rpc`] framing, `WAIT`
//!   long-polling the scheduler's completion condvar server-side,
//!   results streamed in chunks. The wire grammar is documented in
//!   `docs/serve.md`.
//! * [`client`] — [`RemoteClient`]`<T>`, the wire implementation of
//!   [`Client`](crate::client::Client); [`ServeClient`] is its
//!   Unix-socket instantiation.
//! * [`jobs`] — the job spec: a [`crate::plan::Plan`] (multi-stage
//!   pipelines in the sectioned plan format, or the historical flat
//!   `key = value` single-op form lowered to a one-stage plan) plus the
//!   session resolved over the server session via
//!   [`crate::session::Session::overlay_config`]; the queued → running →
//!   done/failed state machine; and the wire codecs for statuses and
//!   result tables. Errors propagate as typed
//!   [`crate::error::UniGpsError`] values end to end — ERR frames carry
//!   the error kind, so clients get the same variant back.
//! * [`cache`] — the shared graph-snapshot cache: `Arc<Graph>` keyed by
//!   canonical dataset spec + partition strategy at the dataset level and
//!   by pure-transform chains (`…|sym`) at the derived level,
//!   single-flight loading at both levels (concurrent misses on one key
//!   perform exactly one load/derivation), LRU eviction under a byte
//!   budget, split dataset/derived counters. This is the paper's "one
//!   UniGraph, many programs" sharing made operational — including the
//!   symmetrized views undirected-semantics operators need.
//! * [`scheduler`] — bounded-concurrency execution: a FIFO admission queue
//!   with backpressure (queue full ⇒ typed [`UniGpsError::Backpressure`]
//!   rejection, never unbounded buffering) feeding a fixed pool of job
//!   slots, each executing its job's plan via [`crate::plan::exec`]. The
//!   machine's cores are *split* across slots — every stage runs
//!   [`crate::engine`] with at most `total_workers / slots` workers —
//!   instead of letting N concurrent jobs each spawn `total_workers`
//!   threads and oversubscribe the box. Runners signal a completion
//!   condvar that `WAIT` and in-process waiters park on.
//!
//! [`UniGpsError::Backpressure`]: crate::error::UniGpsError::Backpressure
//!
//! ```no_run
//! use unigps::client::Client;
//! use unigps::serve::{ServeClient, ServeConfig, Server};
//! use unigps::session::Session;
//! use std::path::Path;
//!
//! // Server (normally `unigps serve --socket /tmp/unigps.sock`,
//! // optionally `--tcp 0.0.0.0:7077 --token-file tok`):
//! let cfg = ServeConfig::new("/tmp/unigps.sock");
//! let server = Server::bind(Session::builder().build(), cfg).unwrap();
//! std::thread::spawn(move || server.run().unwrap());
//!
//! // Client (normally `unigps submit ...`); over TCP this would be
//! // `RemoteClient::connect_tcp("host:7077", "token")` — same trait.
//! let mut client = ServeClient::connect(Path::new("/tmp/unigps.sock")).unwrap();
//! let id = client.submit("algo = pagerank\ndataset = lj\nscale = 1024").unwrap();
//! let result = client.wait(id, std::time::Duration::from_secs(60)).unwrap();
//! println!("{}", result.metrics.summary());
//! ```

pub mod cache;
pub mod client;
pub mod jobs;
pub mod scheduler;
pub mod server;
pub mod transport;

pub use cache::{CacheStats, SnapshotCache};
pub use client::{RemoteClient, ServeClient};
pub use jobs::{DatasetRef, JobId, JobSpec, JobState, JobStatus};
pub use scheduler::{SchedStats, Scheduler};
pub use server::{ServeStats, Server};
pub use transport::{TcpTransport, Transport, UdsTransport};

use std::path::{Path, PathBuf};

/// Serving-protocol method indices, extending
/// [`crate::ipc::protocol::method`] (indices 0–8 belong to the VCProg
/// isolation protocol; serving methods start at 16).
pub mod method {
    /// Submit a job spec (`key = value` text); response is the `u64` job id.
    pub const SUBMIT: u32 = 16;
    /// Query a job's status by id; response is an encoded
    /// [`super::JobStatus`].
    pub const STATUS: u32 = 17;
    /// Fetch a finished job's result table by id; answered with a
    /// `RESULT_BEGIN / RESULT_CHUNK / RESULT_END` stream
    /// ([`super::transport::reply`]), any table size.
    pub const RESULT: u32 = 18;
    /// Fetch server-wide cache + scheduler statistics.
    pub const STATS: u32 = 19;
    /// Submit a wire-encoded [`crate::plan::Plan`]
    /// ([`crate::plan::wire::encode_plan`]); response is the `u64` job id.
    pub const SUBMIT_PLAN: u32 = 20;
    /// Authentication handshake: payload is the preshared token.
    /// Mandatory first frame on TCP connections; a no-op courtesy on the
    /// Unix socket.
    pub const HELLO: u32 = 21;
    /// Long-poll a job: `u64 id | u64 timeout_ms`. The server parks on
    /// the scheduler's completion condvar (clamped to
    /// [`super::server::MAX_WAIT_SLICE_MS`]) and responds with the job's
    /// [`super::JobStatus`], terminal or not.
    pub const WAIT: u32 = 22;
    /// Cooperatively cancel a job: payload is the `u64` job id; response
    /// is the job's [`super::JobStatus`] after the cancel was applied (a
    /// running job may still report `Running` — it unwinds to `Cancelled`
    /// within about one superstep; long-poll with `WAIT` to observe it).
    pub const CANCEL: u32 = 23;
    /// Fetch a versioned snapshot of the process-wide metrics registry
    /// ([`crate::obs::metrics::snapshot`]), encoded with
    /// [`crate::obs::metrics::MetricsSnapshot::encode`]. Empty payload.
    pub const METRICS: u32 = 24;
    /// Apply a delta batch ([`crate::delta::DeltaBatch`] text form)
    /// against the current generation of its dataset, producing
    /// generation N+1 (`docs/evolving.md`); response is an encoded
    /// [`crate::delta::IngestReceipt`] (new epoch + edge counts).
    pub const INGEST: u32 = 25;
    /// Orderly server shutdown (drains queued and running jobs first).
    pub use crate::ipc::protocol::method::SHUTDOWN;
}

/// Configuration of a serving instance.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Unix-domain socket path the server listens on (always bound).
    pub socket: PathBuf,
    /// Optional TCP listen address (`host:port`; port 0 picks a free
    /// port, readable via [`Server::tcp_addr`]). Requires `token`.
    pub tcp: Option<String>,
    /// Preshared auth token TCP clients must present in their HELLO
    /// frame. Mandatory when `tcp` is set; optional hardening otherwise.
    pub token: Option<String>,
    /// Per-chunk payload size for streamed result tables (clamped into
    /// `1..=MAX_FRAME_LEN` at write time).
    pub chunk_len: usize,
    /// Maximum jobs executing concurrently (scheduler slots).
    pub slots: usize,
    /// Admission-queue capacity; submits beyond it are rejected with a
    /// typed error (backpressure, not buffering).
    pub queue_cap: usize,
    /// Snapshot-cache memory budget in bytes (LRU-evicted above this).
    pub cache_budget: usize,
    /// Total worker threads to split across the slots. Each job runs with
    /// `max(1, total_workers / slots)` workers (a spec asking for fewer
    /// keeps its smaller count).
    pub total_workers: usize,
    /// Per-connection socket read timeout on the server side. Must exceed
    /// the `WAIT` long-poll slice
    /// ([`server::MAX_WAIT_SLICE_MS`]) or idle-but-healthy waiting clients
    /// would be dropped; an idle or wedged client past it releases its
    /// handler thread. `None` disables the timeout.
    pub read_timeout: Option<std::time::Duration>,
    /// Per-connection socket write timeout on the server side: a client
    /// that stops draining a streamed result cannot pin a handler thread.
    /// `None` disables the timeout.
    pub write_timeout: Option<std::time::Duration>,
    /// Jobs whose queue-wait + run time exceeds this are logged to stderr
    /// with their rendered trace profile (the slow-job log,
    /// `docs/observability.md`). `None` disables the log.
    pub slow_job_threshold: Option<std::time::Duration>,
}

impl ServeConfig {
    /// Defaults: 2 slots over all available cores, a 64-job queue, a
    /// 512 MiB snapshot budget, 4 MiB result chunks, no TCP listener.
    pub fn new(socket: impl Into<PathBuf>) -> ServeConfig {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        ServeConfig {
            socket: socket.into(),
            tcp: None,
            token: None,
            chunk_len: transport::DEFAULT_CHUNK_LEN,
            slots: 2,
            queue_cap: 64,
            cache_budget: 512 << 20,
            total_workers: cores,
            read_timeout: Some(std::time::Duration::from_secs(120)),
            write_timeout: Some(std::time::Duration::from_secs(30)),
            slow_job_threshold: None,
        }
    }

    /// Sizing for an in-process executor
    /// ([`LocalClient`](crate::client::LocalClient)): same scheduler
    /// defaults as [`ServeConfig::new`], no transport — the socket path
    /// is a placeholder that is never bound.
    pub fn in_process() -> ServeConfig {
        ServeConfig::new("/unigps-in-process-never-bound")
    }

    /// Worker threads each job slot runs with (cores split across slots,
    /// never oversubscribed).
    pub fn per_job_workers(&self) -> usize {
        (self.total_workers / self.slots.max(1)).max(1)
    }

    /// The socket path.
    pub fn socket(&self) -> &Path {
        &self.socket
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_job_workers_splits_cores() {
        let mut cfg = ServeConfig::new("/tmp/x.sock");
        cfg.total_workers = 8;
        cfg.slots = 2;
        assert_eq!(cfg.per_job_workers(), 4);
        cfg.slots = 3;
        assert_eq!(cfg.per_job_workers(), 2);
        // More slots than cores still grants every job one worker.
        cfg.slots = 16;
        assert_eq!(cfg.per_job_workers(), 1);
        // Degenerate slot counts never divide by zero.
        cfg.slots = 0;
        assert_eq!(cfg.per_job_workers(), 8);
    }

    #[test]
    fn method_indices_do_not_collide_with_vcprog_protocol() {
        use crate::ipc::protocol::method as vc;
        for m in [
            method::SUBMIT,
            method::STATUS,
            method::RESULT,
            method::STATS,
            method::SUBMIT_PLAN,
            method::HELLO,
            method::WAIT,
            method::CANCEL,
            method::METRICS,
            method::INGEST,
        ] {
            for v in [
                vc::INIT_PROGRAM,
                vc::EMPTY_MESSAGE,
                vc::INIT_VERTEX,
                vc::MERGE,
                vc::COMPUTE,
                vc::EMIT,
                vc::PING,
                vc::SHUTDOWN,
                vc::EMIT_BATCH,
            ] {
                assert_ne!(m, v);
            }
        }
    }
}
