//! Bounded-concurrency job scheduler with FIFO admission and backpressure.
//!
//! Submits are parsed ([`JobSpec::parse`] or the wire plan codec) before
//! admission, so malformed specs fail fast with typed
//! [`UniGpsError::Config`] errors and never occupy queue space. Admitted
//! jobs enter a FIFO queue of bounded capacity; when it is full,
//! [`Scheduler::submit`] returns a typed [`UniGpsError::Backpressure`]
//! rejection — backpressure the client sees (and can match on, end to
//! end, thanks to the kind-tagged ERR frames), instead of unbounded
//! server-side buffering. A fixed pool of runner threads ("slots") drains
//! the queue; each job executes its **plan** through
//! [`crate::plan::exec::execute`] with a cache-backed snapshot store, so
//! base snapshots resolve through [`SnapshotCache::get_or_load`] and pure
//! transform variants (symmetrize, relabel) through
//! [`SnapshotCache::get_or_derive`] — N concurrent identical pipelines
//! cost one load plus one derivation. Every stage is capped at
//! `min(requested, total_workers / slots)` engine workers so concurrent
//! jobs split the machine's cores instead of oversubscribing them.
//!
//! **Cancellation.** Every job owns a [`CancelToken`] threaded into its
//! plan's engine runs. [`Scheduler::cancel`] cancels a queued job in place
//! and raises a running job's token (it unwinds within about one
//! superstep to the `Cancelled` terminal state); a per-job `deadline_ms`
//! arms a watchdog thread that does the same when the deadline passes; and
//! [`Scheduler::drain`] gives in-flight jobs a grace period at shutdown
//! before cancelling the stragglers, so a wedged job can no longer stall
//! graceful drain forever.
//!
//! [`UniGpsError::Config`]: crate::error::UniGpsError::Config
//! [`UniGpsError::Backpressure`]: crate::error::UniGpsError::Backpressure
//! [`SnapshotCache::get_or_load`]: crate::serve::cache::SnapshotCache::get_or_load
//! [`SnapshotCache::get_or_derive`]: crate::serve::cache::SnapshotCache::get_or_derive

use crate::delta::{DeltaBatch, IngestReceipt};
use crate::engine::RunResult;
use crate::error::{Result, UniGpsError};
use crate::graph::Graph;
use crate::plan::exec::{execute, GraphHandle, SnapshotStore};
use crate::serve::cache::{generation_key, SnapshotCache};
use crate::serve::jobs::{JobId, JobSpec, JobState, JobStatus};
use crate::serve::ServeConfig;
use crate::session::Session;
use crate::util::sync::{CancelToken, Condvar, Mutex};
use crate::util::timer::monotonic_micros;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Scheduler observability counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedStats {
    /// Jobs admitted to the queue.
    pub submitted: u64,
    /// Submits rejected by backpressure (queue full or shutting down).
    pub rejected: u64,
    /// Jobs finished successfully.
    pub completed: u64,
    /// Jobs finished with an error.
    pub failed: u64,
    /// Jobs cancelled (client `CANCEL`, deadline watchdog, or drain).
    pub cancelled: u64,
    /// Jobs currently waiting in the queue.
    pub queued: usize,
    /// Jobs currently executing.
    pub running: usize,
}

/// Finished jobs (Done or Failed) retained for status/result queries;
/// older ones are evicted in completion order so a long-lived server's
/// job table — which holds full result columns — stays bounded.
pub const MAX_FINISHED_JOBS: usize = 1024;

struct JobRecord {
    spec: JobSpec,
    state: JobState,
    error: Option<String>,
    result: Option<Arc<RunResult>>,
    /// Per-job cancellation token, shared with the engine runtime while the
    /// job runs. Raised by [`Scheduler::cancel`], the deadline watchdog, or
    /// the drain grace period.
    cancel: CancelToken,
    /// Absolute deadline resolved from `spec.deadline_ms` at admission
    /// (`None` = no deadline). The clock covers queue time.
    deadline: Option<Instant>,
    /// Admission time, µs on the process monotonic epoch — feeds the
    /// queue-wait histogram and the "queued" trace span.
    submitted_at_us: u64,
    /// Rendered trace profile, attached at the terminal transition and
    /// served inside [`JobStatus`].
    profile: Option<String>,
}

struct Inner {
    queue: VecDeque<JobId>,
    jobs: HashMap<JobId, JobRecord>,
    /// Terminal jobs in completion order (the eviction queue).
    finished: VecDeque<JobId>,
    next_id: JobId,
    submitted: u64,
    rejected: u64,
    completed: u64,
    failed: u64,
    cancelled: u64,
    running: usize,
    shutdown: bool,
}

struct Shared {
    inner: Mutex<Inner>,
    /// Signals runners that work (or shutdown) is available.
    work: Condvar,
    /// Signals waiters ([`Scheduler::wait_terminal`], the server's `WAIT`
    /// long-poll) that some job reached a terminal state.
    done: Condvar,
    /// Signals the deadline watchdog that its schedule may have changed
    /// (new job with a deadline, shutdown). Separate from `work` so a
    /// submit's `notify_one` can never be consumed by the watchdog instead
    /// of a runner.
    watch: Condvar,
    cache: Arc<SnapshotCache>,
    /// The server session job specs are layered over.
    base: Session,
    queue_cap: usize,
    /// Per-slot engine worker budget (cores split across slots).
    job_workers: usize,
    /// Jobs slower than this (queue wait + run) are logged with their
    /// trace profile ([`ServeConfig::slow_job_threshold`]).
    slow_job_threshold: Option<Duration>,
}

/// Default grace period [`Scheduler::shutdown`] allows in-flight jobs
/// before cancelling them (see [`Scheduler::drain`]).
pub const DEFAULT_DRAIN_GRACE: Duration = Duration::from_secs(30);

/// The job scheduler. Create with [`Scheduler::start`]; share via `Arc`.
pub struct Scheduler {
    shared: Arc<Shared>,
    runners: Mutex<Vec<JoinHandle<()>>>,
    watchdog: Mutex<Option<JoinHandle<()>>>,
}

impl Scheduler {
    /// Start `cfg.slots` runner threads over `cache`. (A zero-slot
    /// scheduler admits but never executes — useful for deterministic
    /// backpressure tests.)
    pub fn start(base: Session, cache: Arc<SnapshotCache>, cfg: &ServeConfig) -> Scheduler {
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                jobs: HashMap::new(),
                finished: VecDeque::new(),
                next_id: 1,
                submitted: 0,
                rejected: 0,
                completed: 0,
                failed: 0,
                cancelled: 0,
                running: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            watch: Condvar::new(),
            cache,
            base,
            queue_cap: cfg.queue_cap.max(1),
            job_workers: cfg.per_job_workers(),
            slow_job_threshold: cfg.slow_job_threshold,
        });
        let runners = (0..cfg.slots)
            .map(|slot| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("unigps-slot-{slot}"))
                    // lint: allow-panic: slots spawn once at server startup,
                    // never on a client request path.
                    .spawn(move || runner_loop(&shared))
                    .expect("spawn scheduler slot")
            })
            .collect();
        let watchdog = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("unigps-watchdog".into())
                // lint: allow-panic: spawned once at server startup, never
                // on a client request path.
                .spawn(move || watchdog_loop(&shared))
                .expect("spawn deadline watchdog")
        };
        Scheduler {
            shared,
            runners: Mutex::new(runners),
            watchdog: Mutex::new(Some(watchdog)),
        }
    }

    /// Parse and admit a job. Typed failures: [`UniGpsError::Config`] for
    /// bad specs, [`UniGpsError::Backpressure`] when the queue is full,
    /// [`UniGpsError::Serve`] when the scheduler is shutting down.
    ///
    /// [`UniGpsError::Config`]: crate::error::UniGpsError::Config
    /// [`UniGpsError::Backpressure`]: crate::error::UniGpsError::Backpressure
    /// [`UniGpsError::Serve`]: crate::error::UniGpsError::Serve
    pub fn submit(&self, spec_text: &str) -> Result<JobId> {
        let spec = JobSpec::parse(spec_text, &self.shared.base)?;
        self.submit_spec(spec)
    }

    /// Validate and admit a wire-decoded [`Plan`](crate::plan::Plan) (the
    /// `SUBMIT_PLAN` method): [`JobSpec::from_plan`] applies the same
    /// source caps and structural checks as text parsing.
    pub fn submit_plan(&self, plan: crate::plan::Plan) -> Result<JobId> {
        let spec = JobSpec::from_plan(plan, &self.shared.base)?;
        self.submit_spec(spec)
    }

    /// Admit an already-validated job (text and plan submits land here).
    /// Same typed rejections as [`Scheduler::submit`].
    pub fn submit_spec(&self, spec: JobSpec) -> Result<JobId> {
        let obs = crate::obs::metrics::registry();
        let mut inner = self.shared.inner.lock().unwrap();
        if inner.shutdown {
            inner.rejected += 1;
            obs.jobs_rejected.inc();
            return Err(UniGpsError::serve("scheduler is shutting down"));
        }
        if inner.queue.len() >= self.shared.queue_cap {
            inner.rejected += 1;
            obs.jobs_rejected.inc();
            return Err(UniGpsError::backpressure(format!(
                "queue full ({} jobs queued, capacity {}); retry later",
                inner.queue.len(),
                self.shared.queue_cap
            )));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        let deadline = (spec.deadline_ms > 0)
            .then(|| Instant::now() + Duration::from_millis(spec.deadline_ms));
        inner.jobs.insert(
            id,
            JobRecord {
                spec,
                state: JobState::Queued,
                error: None,
                result: None,
                cancel: CancelToken::new(),
                deadline,
                submitted_at_us: monotonic_micros(),
                profile: None,
            },
        );
        inner.queue.push_back(id);
        inner.submitted += 1;
        obs.jobs_submitted.inc();
        publish_gauges(&inner);
        drop(inner);
        self.shared.work.notify_one();
        if deadline.is_some() {
            // The watchdog re-derives its next wake-up from the job table.
            self.shared.watch.notify_one();
        }
        Ok(id)
    }

    /// Apply a delta batch (text form, [`DeltaBatch::parse`]) against the
    /// current generation of its dataset, producing generation N+1 — the
    /// `INGEST` wire method and `LocalClient::ingest` land here. The
    /// cache serializes ingests per dataset and keeps superseded
    /// generations readable for epoch-pinned plans (`generation = N`);
    /// jobs without a pin resolve `latest` at run start. Typed failures
    /// mirror submit: [`UniGpsError::Config`] for malformed or
    /// inapplicable batches, [`UniGpsError::Backpressure`] at the
    /// generation cap, [`UniGpsError::Serve`] when shutting down.
    ///
    /// [`UniGpsError::Config`]: crate::error::UniGpsError::Config
    /// [`UniGpsError::Backpressure`]: crate::error::UniGpsError::Backpressure
    /// [`UniGpsError::Serve`]: crate::error::UniGpsError::Serve
    pub fn ingest(&self, batch_text: &str) -> Result<IngestReceipt> {
        if self.shared.inner.lock().unwrap().shutdown {
            return Err(UniGpsError::serve("scheduler is shutting down"));
        }
        let batch = DeltaBatch::parse(batch_text)?;
        let source = batch.source().clone();
        // Generations are keyed under the server session's partition
        // strategy — the same one submitted jobs resolve their base
        // snapshots with.
        let partition = self.shared.base.options().partition.name();
        self.shared
            .cache
            .ingest(Arc::new(batch), partition, &|| source.load(&self.shared.base))
    }

    /// Cooperatively cancel a job. A `Queued` job goes terminal
    /// (`Cancelled`) immediately; a `Running` job has its token raised and
    /// unwinds within about one superstep (the returned status may still
    /// say `Running` — use [`Scheduler::wait_terminal`] to observe the
    /// transition). Terminal jobs are unaffected (cancel is not
    /// retroactive: a `Done` job stays `Done`). Unknown ids are the same
    /// typed [`UniGpsError::Serve`] as [`Scheduler::status`].
    ///
    /// [`UniGpsError::Serve`]: crate::error::UniGpsError::Serve
    pub fn cancel(&self, id: JobId, reason: &str) -> Result<JobStatus> {
        let mut inner = self.shared.inner.lock().unwrap();
        if !inner.jobs.contains_key(&id) {
            return Err(UniGpsError::serve(format!("unknown job {id}")));
        }
        let went_terminal = cancel_locked(&mut inner, id, reason);
        let st = status_of(&inner, id)?;
        drop(inner);
        if went_terminal {
            self.shared.done.notify_all();
        }
        Ok(st)
    }

    /// A job's status. Unknown ids (never assigned, or finished jobs
    /// already evicted past [`MAX_FINISHED_JOBS`]) are the same typed
    /// [`UniGpsError::Serve`] the wire path reports, so in-process and
    /// remote callers see one API.
    ///
    /// [`UniGpsError::Serve`]: crate::error::UniGpsError::Serve
    pub fn status(&self, id: JobId) -> Result<JobStatus> {
        let inner = self.shared.inner.lock().unwrap();
        status_of(&inner, id)
    }

    /// Block until job `id` reaches a terminal state or `timeout`
    /// elapses, returning its status either way (callers check
    /// [`JobState::is_terminal`]). This is the waiter side of the
    /// completion condvar runners signal — the server's `WAIT` long-poll
    /// and [`LocalClient::wait`](crate::client::LocalClient) both park
    /// here instead of polling [`Scheduler::status`]. Unknown ids are
    /// typed errors, including a job evicted *while* waiting.
    pub fn wait_terminal(&self, id: JobId, timeout: Duration) -> Result<JobStatus> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            let st = status_of(&inner, id)?;
            if st.state.is_terminal() {
                return Ok(st);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(st);
            }
            let (guard, _) = self
                .shared
                .done
                .wait_timeout(inner, deadline.saturating_duration_since(now))
                .unwrap();
            inner = guard;
        }
    }

    /// A finished job's result (shared, not deep-copied — the table can be
    /// O(|V|) and this runs under the scheduler lock). Typed
    /// [`UniGpsError::Serve`] when the id is unknown (including evicted
    /// past [`MAX_FINISHED_JOBS`]) or the job is not `Done` (`Failed`
    /// reports the job's own error).
    ///
    /// [`UniGpsError::Serve`]: crate::error::UniGpsError::Serve
    pub fn result(&self, id: JobId) -> Result<Arc<RunResult>> {
        let inner = self.shared.inner.lock().unwrap();
        let rec = inner
            .jobs
            .get(&id)
            .ok_or_else(|| UniGpsError::serve(format!("unknown job {id}")))?;
        match rec.state {
            // lint: allow-panic: Done ⇒ result was set by the runner (an
            // invariant of runner_loop), unreachable from client input.
            JobState::Done => Ok(rec.result.clone().expect("done job has a result")),
            JobState::Failed => Err(UniGpsError::serve(format!(
                "job {id} failed: {}",
                rec.error.as_deref().unwrap_or("unknown error")
            ))),
            // Typed so clients can match `is_cancelled()` — the ERR kind
            // survives the wire round trip (`ErrorKind::Cancelled`).
            JobState::Cancelled => Err(UniGpsError::cancelled(format!(
                "job {id}: {}",
                rec.error.as_deref().unwrap_or("no reason recorded")
            ))),
            state => Err(UniGpsError::serve(format!("job {id} is {state}, not done"))),
        }
    }

    /// Current counters.
    pub fn stats(&self) -> SchedStats {
        let inner = self.shared.inner.lock().unwrap();
        SchedStats {
            submitted: inner.submitted,
            rejected: inner.rejected,
            completed: inner.completed,
            failed: inner.failed,
            cancelled: inner.cancelled,
            queued: inner.queue.len(),
            running: inner.running,
        }
    }

    /// Graceful shutdown with the default grace period
    /// ([`DEFAULT_DRAIN_GRACE`]); see [`Scheduler::drain`]. Idempotent.
    pub fn shutdown(&self) {
        self.drain(DEFAULT_DRAIN_GRACE);
    }

    /// Bounded-time shutdown: refuse new submits, give queued and running
    /// jobs `grace` to finish, then cancel whatever is still live
    /// (reason: "scheduler drain") instead of waiting forever, and join
    /// the runner and watchdog threads. A zero-slot scheduler (test aid)
    /// has nothing to drain its queue, so its queued jobs are cancelled
    /// immediately. Idempotent.
    pub fn drain(&self, grace: Duration) {
        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.shutdown = true;
        }
        self.shared.work.notify_all();
        self.shared.watch.notify_all();
        let handles: Vec<_> = self.runners.lock().unwrap().drain(..).collect();
        let grace = if handles.is_empty() { Duration::ZERO } else { grace };
        let deadline = Instant::now() + grace;
        {
            let mut inner = self.shared.inner.lock().unwrap();
            loop {
                if inner.queue.is_empty() && inner.running == 0 {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    let live: Vec<JobId> = inner
                        .jobs
                        .iter()
                        .filter(|(_, rec)| !rec.state.is_terminal())
                        .map(|(&id, _)| id)
                        .collect();
                    let mut woke = false;
                    for id in live {
                        woke |= cancel_locked(&mut inner, id, "scheduler drain");
                    }
                    if woke {
                        self.shared.done.notify_all();
                    }
                    // Running jobs unwind on their own token within about
                    // one superstep; the joins below bound the wait.
                    break;
                }
                let (guard, _) = self
                    .shared
                    .done
                    .wait_timeout(inner, deadline.saturating_duration_since(now))
                    .unwrap();
                inner = guard;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        // The queue is drained and the runners are gone: wake the watchdog
        // so it observes the exit condition.
        self.shared.watch.notify_all();
        if let Some(h) = self.watchdog.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler").field("stats", &self.stats()).finish()
    }
}

/// One scheduler slot: pop → run → record, until shutdown with an empty
/// queue.
fn runner_loop(shared: &Shared) {
    loop {
        // Pop and mark Running under one lock hold, so a concurrent
        // [`Scheduler::cancel`] can never observe a popped-but-unmarked job
        // and race its terminal transition with ours.
        let (id, spec, cancel, submitted_at_us) = {
            let mut inner = shared.inner.lock().unwrap();
            loop {
                if let Some(id) = inner.queue.pop_front() {
                    // Defensive: cancel_locked purges queue entries when it
                    // cancels a queued job, so a popped id is always live —
                    // but a stale entry must be skipped, never re-run.
                    if !matches!(
                        inner.jobs.get(&id).map(|rec| rec.state),
                        Some(JobState::Queued)
                    ) {
                        continue;
                    }
                    inner.running += 1;
                    // lint: allow-panic: presence was checked just above,
                    // under the same lock hold.
                    let rec = inner.jobs.get_mut(&id).expect("queued job has a record");
                    rec.state = JobState::Running;
                    publish_gauges(&inner);
                    // lint: allow-panic: as above.
                    let rec = inner.jobs.get(&id).expect("queued job has a record");
                    break (id, rec.spec.clone(), rec.cancel.clone(), rec.submitted_at_us);
                }
                if inner.shutdown {
                    return;
                }
                inner = shared.work.wait(inner).unwrap();
            }
        };
        let obs = crate::obs::metrics::registry();
        let run_started_us = monotonic_micros();
        let wait_us = run_started_us.saturating_sub(submitted_at_us);
        if wait_us > 0 {
            obs.sched_queue_wait_us.observe_us(wait_us);
        }
        crate::obs::trace::begin_job(id);
        crate::obs::trace::record("queued", submitted_at_us, run_started_us);
        // A panicking job (malformed generator parameters, engine bug) must
        // not kill the slot thread or wedge the record in Running — it
        // becomes a Failed job like any other error.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(shared, &spec, &cancel)
        }))
        .unwrap_or_else(|payload| {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Err(UniGpsError::serve(format!("job panicked: {msg}")))
        });
        let profile = crate::obs::trace::end_job();
        let rendered = profile.as_deref().map(crate::obs::trace::render);
        let ended_us = monotonic_micros();
        let run_us = ended_us.saturating_sub(run_started_us);
        if run_us > 0 {
            obs.sched_run_time_us.observe_us(run_us);
        }
        let mut inner = shared.inner.lock().unwrap();
        inner.running -= 1;
        match outcome {
            Ok(result) => {
                inner.completed += 1;
                obs.jobs_completed.inc();
                // lint: allow-panic: running jobs keep their records —
                // eviction only ever touches terminal jobs.
                let rec = inner.jobs.get_mut(&id).expect("running job has a record");
                rec.state = JobState::Done;
                rec.result = Some(Arc::new(result));
                rec.profile = rendered.clone();
            }
            Err(e) if e.is_cancelled() => {
                inner.cancelled += 1;
                obs.jobs_cancelled.inc();
                // lint: allow-panic: as above.
                let rec = inner.jobs.get_mut(&id).expect("running job has a record");
                rec.state = JobState::Cancelled;
                rec.error = Some(e.to_string());
                rec.profile = rendered.clone();
            }
            Err(e) => {
                inner.failed += 1;
                obs.jobs_failed.inc();
                // lint: allow-panic: running jobs keep their records —
                // eviction only ever touches terminal jobs.
                let rec = inner.jobs.get_mut(&id).expect("running job has a record");
                rec.state = JobState::Failed;
                rec.error = Some(e.to_string());
                rec.profile = rendered.clone();
            }
        }
        finish_record(&mut inner, id);
        publish_gauges(&inner);
        drop(inner);
        // Wake every waiter; each rechecks its own job id.
        shared.done.notify_all();
        if let Some(thr) = shared.slow_job_threshold {
            let total_us = ended_us.saturating_sub(submitted_at_us);
            if total_us >= thr.as_micros() as u64 {
                eprintln!(
                    "[unigps serve] slow job {id}: {:.1}ms queue+run (threshold {:.1}ms)\n{}",
                    total_us as f64 / 1e3,
                    thr.as_secs_f64() * 1e3,
                    rendered.as_deref().unwrap_or("(no profile collected)"),
                );
            }
        }
    }
}

/// Cancel under the scheduler lock. `Queued` → terminal `Cancelled` in
/// place (the stale queue entry is purged); `Running` → raise the token
/// and let the runner record the terminal state; terminal states are
/// untouched. Returns whether a job went terminal here (the caller must
/// then notify the `done` condvar).
fn cancel_locked(inner: &mut Inner, id: JobId, reason: &str) -> bool {
    let Some(rec) = inner.jobs.get_mut(&id) else {
        return false;
    };
    match rec.state {
        JobState::Queued => {
            rec.state = JobState::Cancelled;
            rec.error = Some(format!("cancelled: {reason}"));
            rec.cancel.cancel(reason);
            inner.cancelled += 1;
            crate::obs::metrics::registry().jobs_cancelled.inc();
            inner.queue.retain(|&q| q != id);
            finish_record(inner, id);
            publish_gauges(inner);
            true
        }
        JobState::Running => {
            rec.cancel.cancel(reason);
            false
        }
        _ => false,
    }
}

/// Deadline watchdog: sleeps until the earliest live deadline (or
/// indefinitely when none is set), cancels overdue jobs, and exits once
/// the scheduler has shut down with nothing left to watch.
fn watchdog_loop(shared: &Shared) {
    let mut inner = shared.inner.lock().unwrap();
    loop {
        let now = Instant::now();
        let mut next: Option<Instant> = None;
        let mut overdue: Vec<JobId> = Vec::new();
        for (&id, rec) in inner.jobs.iter() {
            if rec.state.is_terminal() {
                continue;
            }
            match rec.deadline {
                Some(dl) if dl <= now => overdue.push(id),
                Some(dl) => next = Some(next.map_or(dl, |n| n.min(dl))),
                None => {}
            }
        }
        let mut woke = false;
        for id in overdue {
            woke |= cancel_locked(&mut inner, id, "deadline exceeded");
        }
        if woke {
            shared.done.notify_all();
        }
        if inner.shutdown && inner.queue.is_empty() && inner.running == 0 {
            return;
        }
        inner = match next {
            Some(dl) => {
                let (guard, _) = shared
                    .watch
                    .wait_timeout(inner, dl.saturating_duration_since(now))
                    .unwrap();
                guard
            }
            None => shared.watch.wait(inner).unwrap(),
        };
    }
}

/// Status snapshot under the lock; unknown ids are typed errors.
fn status_of(inner: &Inner, id: JobId) -> Result<JobStatus> {
    inner
        .jobs
        .get(&id)
        .map(|rec| JobStatus {
            id,
            state: rec.state,
            error: rec.error.clone(),
            profile: rec.profile.clone(),
        })
        .ok_or_else(|| UniGpsError::serve(format!("unknown job {id}")))
}

/// Refresh the queue-depth and running-jobs gauges from the locked state —
/// gauges are set, never incremented, so they cannot drift from the truth
/// the scheduler lock protects.
fn publish_gauges(inner: &Inner) {
    let obs = crate::obs::metrics::registry();
    obs.queue_depth.set(inner.queue.len() as u64);
    obs.jobs_running.set(inner.running as u64);
}

/// Record a terminal job in completion order and evict the oldest finished
/// records beyond [`MAX_FINISHED_JOBS`] — a resident server must not
/// retain every result table it ever produced.
fn finish_record(inner: &mut Inner, id: JobId) {
    inner.finished.push_back(id);
    while inner.finished.len() > MAX_FINISHED_JOBS {
        if let Some(old) = inner.finished.pop_front() {
            inner.jobs.remove(&old);
        }
    }
}

/// Cache-backed [`SnapshotStore`]: pure-transform variants resolve
/// through derived keys (`<base>|sym`, ...) with the same single-flight
/// discipline as the base snapshot, so N concurrent identical plans share
/// one load and one derivation.
struct CachedStore<'a> {
    cache: &'a SnapshotCache,
    base_key: String,
}

impl SnapshotStore for CachedStore<'_> {
    fn derived(
        &mut self,
        chain: &[&'static str],
        derive: &mut dyn FnMut() -> Result<Graph>,
    ) -> Result<Arc<Graph>> {
        let key = format!("{}|{}", self.base_key, chain.join("|"));
        self.cache.get_or_derive(&key, derive)
    }
}

/// Execute one job: resolve the base snapshot through the dataset-level
/// cache, run the plan with a derived-key store, capping every stage at
/// the slot's core share. `cancel` is polled during the synthetic delay
/// and threaded into every plan stage's engine run.
fn run_job(shared: &Shared, spec: &JobSpec, cancel: &CancelToken) -> Result<RunResult> {
    // Sliced sleep so a cancel during the synthetic service delay frees
    // the slot in ~20 ms instead of the full delay.
    let mut remaining = spec.delay_ms;
    while remaining > 0 {
        if cancel.is_cancelled() {
            return Err(UniGpsError::cancelled(cancel.reason()));
        }
        let slice = remaining.min(20);
        std::thread::sleep(std::time::Duration::from_millis(slice));
        remaining -= slice;
    }
    // Chaos harness: a slot that fails here must record a Failed job and
    // keep serving — never a dead slot or a record wedged in Running.
    if let Some(act) = crate::util::fault::point!("sched-run") {
        act.apply("sched-run")?;
    }
    let source = spec.dataset();
    let canonical = source.canonical();
    // The base key carries the job's partition strategy (resolved from
    // the plan defaults) so future partition-resident layouts can slot in
    // without a key change; the snapshot bytes themselves are
    // partition-independent.
    let partition = spec.session.options().partition.name();
    // `generation = latest` (the default) resolves to the dataset's
    // current epoch at run start; a numeric pin answers from that epoch's
    // snapshot even after later ingests (readable until evicted).
    let epoch = match spec.plan.defaults.get("generation") {
        None => shared.cache.generation(&canonical),
        Some("latest") => shared.cache.generation(&canonical),
        Some(pin) => pin.trim().parse::<u64>().map_err(|_| {
            UniGpsError::Config(format!(
                "generation must be `latest` or an epoch number, got `{pin}`"
            ))
        })?,
    };
    let base_key = generation_key(&canonical, partition, epoch);
    let base = crate::obs::trace::span(&format!("load snapshot {base_key}"), || {
        shared
            .cache
            .get_or_load_generation(&canonical, partition, epoch, &|| source.load(&shared.base))
    })?;
    let mut store = CachedStore {
        cache: &shared.cache,
        base_key,
    };
    let out = execute(
        &spec.plan,
        &spec.session,
        GraphHandle::Shared(base),
        &mut store,
        shared.job_workers,
        cancel,
    )?;
    Ok(out.result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineKind, RunOptions};
    use crate::operators::run_operator;
    use std::time::{Duration, Instant};

    fn cfg(slots: usize, queue_cap: usize) -> ServeConfig {
        let mut c = ServeConfig::new("/tmp/unused.sock");
        c.slots = slots;
        c.queue_cap = queue_cap;
        c.total_workers = 4;
        c
    }

    fn wait_done(sched: &Scheduler, id: JobId) -> JobStatus {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let st = sched.status(id).expect("known job");
            if st.state.is_terminal() {
                return st;
            }
            assert!(Instant::now() < deadline, "job {id} stuck in {}", st.state);
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    const SPEC: &str = "algo = sssp\nvertices = 96\nedges = 384\nseed = 3\nworkers = 2";

    #[test]
    fn submit_run_and_fetch_result() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        let id = sched.submit(SPEC).unwrap();
        let st = wait_done(&sched, id);
        assert_eq!(st.state, JobState::Done, "error: {:?}", st.error);
        let result = sched.result(id).unwrap();
        // Identical to a direct engine run with the same split options.
        let g = Session::builder().build().generate("rmat", 96, 384, 3);
        let opts = RunOptions::default().with_workers(2);
        let direct = run_operator(
            &g,
            &crate::operators::Operator::Sssp { root: 0 },
            EngineKind::Pregel,
            &opts,
        )
        .unwrap();
        assert_eq!(result.columns, direct.columns);
        let s = sched.stats();
        assert_eq!((s.completed, s.failed, s.queued, s.running), (1, 0, 0, 0));
        sched.shutdown();
    }

    #[test]
    fn done_jobs_carry_a_trace_profile() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        let id = sched.submit(SPEC).unwrap();
        let st = wait_done(&sched, id);
        assert_eq!(st.state, JobState::Done, "error: {:?}", st.error);
        let profile = st.profile.expect("terminal jobs attach a rendered profile");
        assert!(profile.contains(&format!("job {id} profile")), "{profile}");
        assert!(profile.contains("queued"), "{profile}");
        assert!(profile.contains("load snapshot"), "{profile}");
        assert!(profile.contains("stage 0: sssp"), "{profile}");
        sched.shutdown();
    }

    #[test]
    fn queue_full_is_a_typed_rejection() {
        // Zero slots: nothing drains, so admission is deterministic.
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(0, 3),
        );
        for _ in 0..3 {
            sched.submit(SPEC).unwrap();
        }
        let err = sched.submit(SPEC).unwrap_err();
        assert!(matches!(err, UniGpsError::Backpressure(_)), "got {err:?}");
        assert!(err.is_backpressure());
        assert!(err.to_string().contains("queue full"), "{err}");
        let s = sched.stats();
        assert_eq!((s.submitted, s.rejected, s.queued), (3, 1, 3));
        sched.shutdown();
    }

    #[test]
    fn bad_specs_fail_before_admission() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(0, 4),
        );
        let err = sched.submit("algo = warp\nvertices = 8").unwrap_err();
        assert!(matches!(err, UniGpsError::Config(_)));
        assert_eq!(sched.stats().queued, 0, "parse failures take no queue space");
        sched.shutdown();
    }

    #[test]
    fn failed_jobs_report_their_error() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 4),
        );
        let id = sched.submit("algo = cc\ndataset = atlantis").unwrap();
        let st = wait_done(&sched, id);
        assert_eq!(st.state, JobState::Failed);
        assert!(st.error.as_deref().unwrap_or("").contains("unknown dataset"));
        let err = sched.result(id).unwrap_err();
        assert!(matches!(err, UniGpsError::Serve(_)));
        assert_eq!(sched.stats().failed, 1);
        sched.shutdown();
    }

    #[test]
    fn hostile_specs_rejected_and_slot_survives_failures() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        // `scale = 0` would divide by zero inside the dataset generator;
        // the spec layer rejects it (typed) before it can panic a slot.
        let bad = sched.submit("algo = cc\ndataset = lj\nscale = 0").unwrap_err();
        assert!(matches!(bad, UniGpsError::Config(_)), "scale=0 rejected at parse: {bad:?}");
        // Should a panic ever slip past the parse caps, runner_loop's
        // catch_unwind turns it into a Failed job instead of a dead slot.
        // Either way the slot must keep serving after a failed job:
        let id = sched.submit("algo = cc\ndataset = atlantis").unwrap();
        assert_eq!(wait_done(&sched, id).state, JobState::Failed);
        let id = sched.submit(SPEC).unwrap();
        assert_eq!(wait_done(&sched, id).state, JobState::Done, "slot survives failures");
        sched.shutdown();
    }

    #[test]
    fn shutdown_drains_admitted_jobs() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(2, 16),
        );
        let ids: Vec<_> = (0..6).map(|_| sched.submit(SPEC).unwrap()).collect();
        sched.shutdown();
        for id in ids {
            let st = sched.status(id).unwrap();
            assert_eq!(st.state, JobState::Done, "job {id} not drained: {:?}", st.error);
        }
        let err = sched.submit(SPEC).unwrap_err();
        assert!(err.to_string().contains("shutting down"));
    }

    #[test]
    fn unknown_job_queries_are_typed() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(0, 2),
        );
        let err = sched.status(999).unwrap_err();
        assert!(matches!(err, UniGpsError::Serve(_)), "{err:?}");
        assert!(err.to_string().contains("unknown job"), "{err}");
        let err = sched.result(999).unwrap_err();
        assert!(matches!(err, UniGpsError::Serve(_)));
        let err = sched.wait_terminal(999, Duration::from_millis(1)).unwrap_err();
        assert!(matches!(err, UniGpsError::Serve(_)), "{err:?}");
        sched.shutdown();
    }

    #[test]
    fn cancel_queued_job_goes_terminal_immediately() {
        // Zero slots: the job can never start, so cancellation is the only
        // way it goes terminal.
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(0, 4),
        );
        let id = sched.submit(SPEC).unwrap();
        let st = sched.cancel(id, "client cancel").unwrap();
        assert_eq!(st.state, JobState::Cancelled);
        assert!(st.error.as_deref().unwrap_or("").contains("client cancel"));
        let s = sched.stats();
        assert_eq!((s.cancelled, s.queued), (1, 0), "queue entry purged");
        // Terminal: result is a typed error, wait returns instantly.
        assert!(sched.result(id).is_err());
        let st = sched.wait_terminal(id, Duration::from_secs(5)).unwrap();
        assert_eq!(st.state, JobState::Cancelled);
        // Cancelling again is a no-op.
        let st = sched.cancel(id, "again").unwrap();
        assert_eq!(st.state, JobState::Cancelled);
        assert_eq!(sched.stats().cancelled, 1);
        sched.shutdown();
    }

    #[test]
    fn cancel_running_job_frees_the_slot_for_queued_work() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        // Long synthetic delay keeps the job Running deterministically.
        let slow = sched.submit(&format!("{SPEC}\ndelay_ms = 30000")).unwrap();
        let fast = sched.submit(SPEC).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while sched.status(slow).unwrap().state != JobState::Running {
            assert!(Instant::now() < deadline, "slow job never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        let t = Instant::now();
        sched.cancel(slow, "client cancel").unwrap();
        let st = sched.wait_terminal(slow, Duration::from_secs(10)).unwrap();
        assert_eq!(st.state, JobState::Cancelled, "error: {:?}", st.error);
        assert!(
            t.elapsed() < Duration::from_secs(5),
            "cancel did not wait out the 30 s delay"
        );
        // The freed slot runs the queued job to completion.
        let st = sched.wait_terminal(fast, Duration::from_secs(30)).unwrap();
        assert_eq!(st.state, JobState::Done, "error: {:?}", st.error);
        assert_eq!(sched.stats().cancelled, 1);
        sched.shutdown();
    }

    #[test]
    fn unknown_cancel_is_typed() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(0, 2),
        );
        let err = sched.cancel(999, "nope").unwrap_err();
        assert!(matches!(err, UniGpsError::Serve(_)), "{err:?}");
        sched.shutdown();
    }

    #[test]
    fn deadline_watchdog_cancels_overdue_jobs() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        // The delay far exceeds the deadline: the watchdog must cut it.
        let id = sched
            .submit(&format!("{SPEC}\ndelay_ms = 30000\ndeadline_ms = 100"))
            .unwrap();
        let st = sched.wait_terminal(id, Duration::from_secs(10)).unwrap();
        assert_eq!(st.state, JobState::Cancelled, "error: {:?}", st.error);
        assert!(
            st.error.as_deref().unwrap_or("").contains("deadline"),
            "reason names the deadline: {:?}",
            st.error
        );
        // A queued job's deadline also covers queue time: behind the slow
        // one above there is no slot, so this one expires while Queued.
        let sched2 = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(0, 4),
        );
        let id = sched2.submit(&format!("{SPEC}\ndeadline_ms = 50")).unwrap();
        let st = sched2.wait_terminal(id, Duration::from_secs(10)).unwrap();
        assert_eq!(st.state, JobState::Cancelled);
        sched.shutdown();
        sched2.shutdown();
    }

    #[test]
    fn jobs_without_deadline_are_untouched_by_the_watchdog() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        let id = sched.submit(&format!("{SPEC}\ndelay_ms = 200")).unwrap();
        let st = sched.wait_terminal(id, Duration::from_secs(30)).unwrap();
        assert_eq!(st.state, JobState::Done, "error: {:?}", st.error);
        assert_eq!(sched.stats().cancelled, 0);
        sched.shutdown();
    }

    #[test]
    fn drain_cancels_stragglers_after_grace() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        let slow = sched.submit(&format!("{SPEC}\ndelay_ms = 30000")).unwrap();
        let queued = sched.submit(&format!("{SPEC}\ndelay_ms = 30000")).unwrap();
        let t = Instant::now();
        sched.drain(Duration::from_millis(100));
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "drain bounded by grace + one unwind, not 60 s of delays"
        );
        for id in [slow, queued] {
            let st = sched.status(id).unwrap();
            assert_eq!(st.state, JobState::Cancelled, "job {id}: {:?}", st.error);
            assert!(st.error.as_deref().unwrap_or("").contains("drain"));
        }
        assert_eq!(sched.stats().cancelled, 2);
    }

    #[test]
    fn wait_terminal_blocks_until_done_and_times_out_cleanly() {
        let sched = Scheduler::start(
            Session::builder().build(),
            Arc::new(SnapshotCache::new(usize::MAX)),
            &cfg(1, 8),
        );
        // A job with a service delay: wait_terminal must block past the
        // delay and return Done without polling.
        let id = sched.submit(&format!("{SPEC}\ndelay_ms = 150")).unwrap();
        let t = Instant::now();
        let st = sched.wait_terminal(id, Duration::from_secs(30)).unwrap();
        assert_eq!(st.state, JobState::Done, "error: {:?}", st.error);
        assert!(t.elapsed() >= Duration::from_millis(140), "waited through the delay");
        // A short timeout returns the job's current (non-terminal) state.
        let id = sched.submit(&format!("{SPEC}\ndelay_ms = 2000")).unwrap();
        let st = sched.wait_terminal(id, Duration::from_millis(50)).unwrap();
        assert!(!st.state.is_terminal(), "got {}", st.state);
        sched.shutdown();
    }
}
