//! The serving front end: a Unix-domain-socket accept loop and its client.
//!
//! Reuses the hardened length-prefixed framing of
//! [`crate::ipc::socket_rpc`] (`u32 method_or_status | u32 len | payload`,
//! frames over [`MAX_FRAME_LEN`](crate::ipc::socket_rpc::MAX_FRAME_LEN)
//! rejected before allocation) and the [`crate::ipc::protocol`] status
//! codes. **ERR frames are kind-tagged** ([`encode_error`] /
//! [`decode_error`]): the payload is `u32 error-kind | message`, so
//! [`ServeClient`] rebuilds the *same* [`UniGpsError`] variant the server
//! raised — a queue-full rejection arrives as
//! [`UniGpsError::Backpressure`] and retry loops match on
//! [`UniGpsError::is_backpressure`] instead of substring-matching message
//! text. Each accepted connection gets a handler thread that serves
//! frames until the peer disconnects; all handlers share one
//! [`Scheduler`] and one [`SnapshotCache`](crate::serve::cache::SnapshotCache).
//! A `SHUTDOWN` frame stops the accept loop and drains the scheduler
//! (queued and running jobs finish first).

use crate::engine::RunResult;
use crate::error::{ErrorKind, Result, UniGpsError};
use crate::ipc::protocol::{get_u32, get_u64, put_u64, status};
use crate::ipc::socket_rpc::{connect_with_retry, read_frame, write_frame};
use crate::plan::wire::{decode_plan, encode_plan};
use crate::plan::Plan;
use crate::serve::cache::CacheStats;
use crate::serve::jobs::{decode_result, encode_result, JobId, JobStatus};
use crate::serve::scheduler::{SchedStats, Scheduler};
use crate::serve::{method, ServeConfig};
use crate::session::Session;
use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Encode a typed error for an ERR frame: `u32 kind code | UTF-8 message`.
pub fn encode_error(e: &UniGpsError) -> Vec<u8> {
    let mut out = Vec::new();
    crate::ipc::protocol::put_u32(&mut out, e.kind().code());
    out.extend_from_slice(e.message().as_bytes());
    out
}

/// Decode an ERR frame payload back into the typed error it carried.
/// Malformed payloads degrade to [`UniGpsError::Ipc`], never a panic.
pub fn decode_error(payload: &[u8]) -> UniGpsError {
    let mut pos = 0;
    match get_u32(payload, &mut pos) {
        Ok(code) => ErrorKind::from_code(code)
            .rebuild(String::from_utf8_lossy(&payload[pos..]).into_owned()),
        Err(_) => UniGpsError::ipc(format!(
            "malformed ERR frame: {}",
            String::from_utf8_lossy(payload)
        )),
    }
}

/// Server-wide statistics: snapshot cache + scheduler counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Snapshot-cache counters.
    pub cache: CacheStats,
    /// Scheduler counters.
    pub jobs: SchedStats,
}

impl ServeStats {
    /// Encode for the wire.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for v in [
            self.cache.loads,
            self.cache.hits,
            self.cache.misses,
            self.cache.derived_loads,
            self.cache.derived_hits,
            self.cache.derived_misses,
            self.cache.evictions,
            self.cache.resident,
            self.cache.resident_bytes,
            self.jobs.submitted,
            self.jobs.rejected,
            self.jobs.completed,
            self.jobs.failed,
            self.jobs.queued as u64,
            self.jobs.running as u64,
        ] {
            put_u64(&mut out, v);
        }
        out
    }

    /// Decode from the wire.
    pub fn decode(buf: &[u8]) -> Result<ServeStats> {
        let mut pos = 0;
        let mut take = || get_u64(buf, &mut pos);
        Ok(ServeStats {
            cache: CacheStats {
                loads: take()?,
                hits: take()?,
                misses: take()?,
                derived_loads: take()?,
                derived_hits: take()?,
                derived_misses: take()?,
                evictions: take()?,
                resident: take()?,
                resident_bytes: take()?,
            },
            jobs: SchedStats {
                submitted: take()?,
                rejected: take()?,
                completed: take()?,
                failed: take()?,
                queued: take()? as usize,
                running: take()? as usize,
            },
        })
    }
}

/// The resident job server. Bind, then [`Server::run`] until a client
/// sends `SHUTDOWN`.
pub struct Server {
    listener: UnixListener,
    cfg: ServeConfig,
    sched: Scheduler,
    cache: Arc<crate::serve::cache::SnapshotCache>,
    stop: AtomicBool,
    /// Live connections (socket clones), so shutdown can unblock handler
    /// threads parked in `read_frame` on idle clients. Handlers remove
    /// their own entry on exit, bounding the table to open connections.
    conns: Mutex<HashMap<u64, UnixStream>>,
    next_conn: AtomicU64,
}

impl Server {
    /// Bind the socket (replacing any stale file) and start the scheduler
    /// slots. Job specs are layered over `session` — its engine, worker
    /// count, partition strategy and options are the serving defaults.
    pub fn bind(session: Session, cfg: ServeConfig) -> Result<Server> {
        let _ = std::fs::remove_file(&cfg.socket);
        let listener = UnixListener::bind(&cfg.socket)?;
        let cache = Arc::new(crate::serve::cache::SnapshotCache::new(cfg.cache_budget));
        let sched = Scheduler::start(session, cache.clone(), &cfg);
        Ok(Server {
            listener,
            cfg,
            sched,
            cache,
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(0),
        })
    }

    /// The bound configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Current server-wide statistics.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            cache: self.cache.stats(),
            jobs: self.sched.stats(),
        }
    }

    /// Accept clients until a `SHUTDOWN` frame arrives, then disconnect
    /// remaining clients, drain the scheduler (queued and running jobs
    /// complete) and return. Transient `accept` failures (e.g. fd
    /// exhaustion under many clients) are retried, never fatal.
    pub fn run(&self) -> Result<()> {
        std::thread::scope(|scope| {
            loop {
                if self.stop.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match self.listener.accept() {
                    Ok((stream, _addr)) => stream,
                    Err(_) if self.stop.load(Ordering::SeqCst) => break,
                    Err(_) => {
                        // Transient (EMFILE, EINTR, ...): back off briefly
                        // and keep serving instead of killing the server.
                        std::thread::sleep(Duration::from_millis(20));
                        continue;
                    }
                };
                if self.stop.load(Ordering::SeqCst) {
                    break; // the shutdown waker, or a late connection
                }
                let id = self.next_conn.fetch_add(1, Ordering::SeqCst);
                if let Ok(clone) = stream.try_clone() {
                    self.conns.lock().unwrap().insert(id, clone);
                }
                scope.spawn(move || {
                    // A handler error (protocol violation, broken pipe)
                    // poisons only its own connection.
                    let _ = self.handle_connection(stream);
                    self.conns.lock().unwrap().remove(&id);
                });
            }
            // Refuse new connects fast (path gone beats a backlog hang),
            // then unblock handlers parked on idle clients so the scope
            // can join them.
            let _ = std::fs::remove_file(&self.cfg.socket);
            let remaining: Vec<UnixStream> = self
                .conns
                .lock()
                .unwrap()
                .drain()
                .map(|(_, stream)| stream)
                .collect();
            for stream in remaining {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        });
        self.sched.shutdown();
        Ok(())
    }

    /// Serve one client connection until EOF or `SHUTDOWN`.
    fn handle_connection(&self, stream: UnixStream) -> Result<()> {
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        loop {
            let (m, payload) = match read_frame(&mut reader) {
                Ok(f) => f,
                Err(UniGpsError::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                    return Ok(()); // peer closed
                }
                Err(e) => return Err(e),
            };
            match self.dispatch(m, &payload) {
                // A response over MAX_FRAME_LEN is refused by write_frame
                // *before* any bytes hit the stream, so the connection is
                // still cleanly framed — surface a typed error instead of
                // dropping the client on a raw EOF.
                Ok(resp) => match write_frame(&mut writer, status::OK, &resp) {
                    Err(UniGpsError::Ipc(msg)) => {
                        let e = UniGpsError::ipc(format!(
                            "response too large for one frame: {msg}"
                        ));
                        write_frame(&mut writer, status::ERR, &encode_error(&e))?
                    }
                    other => other?,
                },
                Err(e) => write_frame(&mut writer, status::ERR, &encode_error(&e))?,
            }
            if m == method::SHUTDOWN {
                self.stop.store(true, Ordering::SeqCst);
                // Wake the acceptor so it observes the stop flag.
                let _ = UnixStream::connect(&self.cfg.socket);
                return Ok(());
            }
        }
    }

    fn dispatch(&self, m: u32, payload: &[u8]) -> Result<Vec<u8>> {
        match m {
            method::SUBMIT => {
                let spec = std::str::from_utf8(payload)
                    .map_err(|_| UniGpsError::ipc("submit payload is not UTF-8"))?;
                let id = self.sched.submit(spec)?;
                let mut out = Vec::new();
                put_u64(&mut out, id);
                Ok(out)
            }
            method::SUBMIT_PLAN => {
                let plan = decode_plan(payload)?;
                let id = self.sched.submit_plan(plan)?;
                let mut out = Vec::new();
                put_u64(&mut out, id);
                Ok(out)
            }
            method::STATUS => {
                let mut pos = 0;
                let id = get_u64(payload, &mut pos)?;
                let st = self
                    .sched
                    .status(id)
                    .ok_or_else(|| UniGpsError::serve(format!("unknown job {id}")))?;
                Ok(st.encode())
            }
            method::RESULT => {
                let mut pos = 0;
                let id = get_u64(payload, &mut pos)?;
                Ok(encode_result(&self.sched.result(id)?))
            }
            method::STATS => Ok(self.stats().encode()),
            method::SHUTDOWN => Ok(Vec::new()),
            other => Err(UniGpsError::Ipc(format!("unknown serve method {other}"))),
        }
    }
}

impl std::fmt::Debug for Server {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Server").field("cfg", &self.cfg).finish()
    }
}

/// Client for a [`Server`], one synchronous request at a time (open one
/// client per thread; the server handles connections concurrently).
/// Speaks the strict untrusted framing (`MAX_FRAME_LEN`) the server
/// enforces, and decodes kind-tagged ERR frames back into typed
/// [`UniGpsError`] values.
pub struct ServeClient {
    reader: BufReader<UnixStream>,
    writer: BufWriter<UnixStream>,
}

impl ServeClient {
    /// Connect to a server's socket (retrying briefly while it starts).
    pub fn connect(path: &Path) -> Result<ServeClient> {
        let stream = connect_with_retry(path)?;
        Ok(ServeClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn call(&mut self, m: u32, payload: &[u8]) -> Result<Vec<u8>> {
        write_frame(&mut self.writer, m, payload)?;
        let (st, resp) = read_frame(&mut self.reader)?;
        if st == status::OK {
            Ok(resp)
        } else {
            Err(decode_error(&resp))
        }
    }

    /// Submit a job spec (flat `key = value` text or a sectioned plan
    /// file); returns the job id.
    pub fn submit(&mut self, spec: &str) -> Result<JobId> {
        let resp = self.call(method::SUBMIT, spec.as_bytes())?;
        let mut pos = 0;
        get_u64(&resp, &mut pos)
    }

    /// Submit a [`Plan`] value over the binary wire codec (no text round
    /// trip); returns the job id.
    pub fn submit_plan(&mut self, plan: &Plan) -> Result<JobId> {
        let resp = self.call(method::SUBMIT_PLAN, &encode_plan(plan))?;
        let mut pos = 0;
        get_u64(&resp, &mut pos)
    }

    /// Submit, retrying typed [backpressure](UniGpsError::is_backpressure)
    /// rejections with exponential backoff (4 ms → 256 ms) until
    /// `timeout`. Non-backpressure errors return immediately.
    pub fn submit_with_retry(&mut self, spec: &str, timeout: Duration) -> Result<JobId> {
        let deadline = Instant::now() + timeout;
        let mut backoff = Duration::from_millis(4);
        loop {
            match self.submit(spec) {
                Err(e) if e.is_backpressure() && Instant::now() < deadline => {
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(256));
                }
                other => return other,
            }
        }
    }

    /// Query a job's status.
    pub fn status(&mut self, id: JobId) -> Result<JobStatus> {
        let mut req = Vec::new();
        put_u64(&mut req, id);
        JobStatus::decode(&self.call(method::STATUS, &req)?)
    }

    /// Fetch a finished job's result table.
    pub fn result(&mut self, id: JobId) -> Result<RunResult> {
        let mut req = Vec::new();
        put_u64(&mut req, id);
        decode_result(&self.call(method::RESULT, &req)?)
    }

    /// Fetch server-wide statistics.
    pub fn stats(&mut self) -> Result<ServeStats> {
        ServeStats::decode(&self.call(method::STATS, &[])?)
    }

    /// Poll until the job reaches a terminal state, then return its result
    /// (or the job's typed failure). Errs after `timeout`. Polling backs
    /// off exponentially (2 ms → 128 ms) so long-running jobs don't keep
    /// the server busy answering ~500 status calls per second per waiter.
    pub fn wait(&mut self, id: JobId, timeout: Duration) -> Result<RunResult> {
        let deadline = Instant::now() + timeout;
        let mut backoff = Duration::from_millis(2);
        loop {
            let st = self.status(id)?;
            if st.state.is_terminal() {
                return self.result(id);
            }
            if Instant::now() >= deadline {
                return Err(UniGpsError::serve(format!(
                    "timed out after {timeout:?} waiting for job {id} ({})",
                    st.state
                )));
            }
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(Duration::from_millis(128));
        }
    }

    /// Ask the server to shut down (it drains admitted jobs first).
    pub fn shutdown(&mut self) -> Result<()> {
        self.call(method::SHUTDOWN, &[])?;
        Ok(())
    }
}

impl std::fmt::Debug for ServeClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("ServeClient")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_roundtrip() {
        let s = ServeStats {
            cache: CacheStats {
                loads: 1,
                hits: 11,
                misses: 1,
                derived_loads: 2,
                derived_hits: 9,
                derived_misses: 2,
                evictions: 0,
                resident: 3,
                resident_bytes: 123_456,
            },
            jobs: SchedStats {
                submitted: 12,
                rejected: 2,
                completed: 11,
                failed: 1,
                queued: 3,
                running: 2,
            },
        };
        assert_eq!(ServeStats::decode(&s.encode()).unwrap(), s);
        assert!(ServeStats::decode(&[0u8; 11]).is_err());
    }

    #[test]
    fn error_codec_preserves_the_variant() {
        for e in [
            UniGpsError::backpressure("queue full (64 queued, capacity 64); retry later"),
            UniGpsError::serve("unknown job 9"),
            UniGpsError::Config("unknown algo 'warp'".into()),
            UniGpsError::ipc("frame length 999 exceeds limit"),
        ] {
            let back = decode_error(&encode_error(&e));
            assert_eq!(back.kind(), e.kind(), "{e:?}");
            assert_eq!(back.message(), e.message());
        }
        // Truncated/garbage payloads degrade to Ipc.
        assert!(matches!(decode_error(&[1, 2]), UniGpsError::Ipc(_)));
        assert!(matches!(decode_error(b""), UniGpsError::Ipc(_)));
    }
}
