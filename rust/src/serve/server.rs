//! The serving back end: accept loops over every bound transport.
//!
//! A [`Server`] always listens on its Unix-domain socket and, when
//! [`ServeConfig::tcp`] is set, on a TCP address as well — one protocol,
//! one dispatch table, two byte streams (see
//! [`crate::serve::transport`]). All frames use the hardened
//! length-prefixed framing of [`crate::ipc::socket_rpc`]
//! (`u32 head | u32 len | payload`, payloads over
//! [`MAX_FRAME_LEN`](crate::ipc::socket_rpc::MAX_FRAME_LEN) rejected
//! before allocation, read and write, on both transports).
//!
//! Protocol properties the handlers enforce:
//!
//! * **TCP requires HELLO.** The first frame on a TCP connection must be
//!   `HELLO <preshared token>`; anything else — wrong token included —
//!   is answered with a typed [`UniGpsError::Auth`] ERR frame and the
//!   connection closes, before any job is admitted. Unix-socket clients
//!   are authorized by file permissions and skip the handshake.
//! * **Results stream in chunks.** `RESULT` is answered with
//!   `RESULT_BEGIN | RESULT_CHUNK* | RESULT_END`
//!   ([`write_result_stream`]), so a result table of any size crosses
//!   the wire bit-exact; there is no single-frame result ceiling.
//! * **`WAIT` long-polls server-side.** A `WAIT (id, timeout_ms)` frame
//!   parks the handler on the scheduler's completion condvar
//!   ([`Scheduler::wait_terminal`]) and answers with the job's status —
//!   clients block on one round trip instead of polling `STATUS`.
//! * **ERR frames are kind-tagged** ([`encode_error`] /
//!   [`decode_error`]): the payload is `u32 error-kind | message`, so
//!   clients rebuild the *same* [`UniGpsError`] variant the server
//!   raised — backpressure stays backpressure, auth stays auth.
//!
//! Each accepted connection gets a handler thread that serves frames
//! until the peer disconnects; all handlers share one [`Scheduler`] and
//! one [`SnapshotCache`](crate::serve::cache::SnapshotCache). A
//! `SHUTDOWN` frame stops every accept loop and drains the scheduler
//! (queued and running jobs finish first). The wire grammar is
//! documented in `docs/serve.md`.
//!
//! [`UniGpsError::Auth`]: crate::error::UniGpsError::Auth

use crate::error::{Result, UniGpsError};
use crate::ipc::protocol::{get_u64, put_u64, status};
use crate::ipc::socket_rpc::{read_frame, write_frame};
use crate::plan::wire::decode_plan;
use crate::serve::cache::CacheStats;
use crate::serve::jobs::encode_result;
use crate::serve::scheduler::{SchedStats, Scheduler};
use crate::serve::transport::{
    bind_tcp, bind_uds, tcp_local_addr, write_result_stream, Conn, Listener, MAX_RESULT_LEN,
};
use crate::serve::{method, ServeConfig};
use crate::session::Session;
use crate::util::timer::Timer;
use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// The ERR codec is protocol surface shared with the clients; it lives in
// `transport` now but keeps its historical `server::` paths.
pub use crate::serve::transport::{decode_error, encode_error};

/// Hardest cap on one `WAIT` long-poll's server-side park (30 s). A
/// client asking for more gets its slice clamped and simply sends the
/// next `WAIT`; a handler thread is never parked unboundedly by one
/// frame.
pub const MAX_WAIT_SLICE_MS: u64 = 30_000;

/// How often a parked `WAIT` handler re-checks the server stop flag
/// (250 ms) — bounds how long shutdown waits for long-poll handlers.
const STOP_CHECK_MS: u64 = 250;

/// Server-wide statistics: snapshot cache + scheduler counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Snapshot-cache counters.
    pub cache: CacheStats,
    /// Scheduler counters.
    pub jobs: SchedStats,
}

impl ServeStats {
    /// Encode for the wire. Post-v1 counters travel as trailing fields
    /// after the historical 16 words — first `invalidated`, then the two
    /// mapped-residency words — so older decoders (which stop earlier)
    /// still parse new frames and new decoders accept old frames (the
    /// absent trailing counters read as 0).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for v in [
            self.cache.loads,
            self.cache.hits,
            self.cache.misses,
            self.cache.derived_loads,
            self.cache.derived_hits,
            self.cache.derived_misses,
            self.cache.evictions,
            self.cache.resident,
            self.cache.resident_bytes,
            self.jobs.submitted,
            self.jobs.rejected,
            self.jobs.completed,
            self.jobs.failed,
            self.jobs.cancelled,
            self.jobs.queued as u64,
            self.jobs.running as u64,
            self.cache.invalidated,
            self.cache.mapped_resident,
            self.cache.mapped_resident_bytes,
        ] {
            put_u64(&mut out, v);
        }
        out
    }

    /// Decode from the wire.
    pub fn decode(buf: &[u8]) -> Result<ServeStats> {
        let mut pos = 0;
        let mut take = || get_u64(buf, &mut pos);
        let mut stats = ServeStats {
            cache: CacheStats {
                loads: take()?,
                hits: take()?,
                misses: take()?,
                derived_loads: take()?,
                derived_hits: take()?,
                derived_misses: take()?,
                evictions: take()?,
                invalidated: 0,
                resident: take()?,
                resident_bytes: take()?,
                mapped_resident: 0,
                mapped_resident_bytes: 0,
            },
            jobs: SchedStats {
                submitted: take()?,
                rejected: take()?,
                completed: take()?,
                failed: take()?,
                cancelled: take()?,
                queued: take()? as usize,
                running: take()? as usize,
            },
        };
        // Trailing optionals, in the order they were added to the wire:
        // absent on frames from servers that predate them.
        if pos < buf.len() {
            stats.cache.invalidated = get_u64(buf, &mut pos)?;
        }
        if pos < buf.len() {
            stats.cache.mapped_resident = get_u64(buf, &mut pos)?;
            stats.cache.mapped_resident_bytes = get_u64(buf, &mut pos)?;
        }
        Ok(stats)
    }
}

/// The resident job server. Bind, then [`Server::run`] until a client
/// sends `SHUTDOWN`.
pub struct Server {
    uds: Listener,
    tcp: Option<Listener>,
    tcp_addr: Option<SocketAddr>,
    cfg: ServeConfig,
    sched: Scheduler,
    cache: Arc<crate::serve::cache::SnapshotCache>,
    stop: AtomicBool,
    /// Live connections (socket clones), so shutdown can unblock handler
    /// threads parked in `read_frame` on idle clients. Handlers remove
    /// their own entry on exit, bounding the table to open connections.
    conns: Mutex<HashMap<u64, Conn>>,
    next_conn: AtomicU64,
}

impl Server {
    /// Bind the Unix socket (replacing any stale file), bind the TCP
    /// listener when [`ServeConfig::tcp`] is set — refusing a TCP
    /// configuration without a preshared token, since an unauthenticated
    /// network listener must never exist — and start the scheduler
    /// slots. Job specs are layered over `session` — its engine, worker
    /// count, partition strategy and options are the serving defaults.
    pub fn bind(session: Session, cfg: ServeConfig) -> Result<Server> {
        if cfg.tcp.is_some() && cfg.token.as_deref().unwrap_or("").is_empty() {
            return Err(UniGpsError::Config(
                "TCP serving requires a preshared token (serve --tcp needs \
                 --token-file); refusing to bind an unauthenticated listener"
                    .into(),
            ));
        }
        let uds = bind_uds(&cfg.socket)?;
        let tcp = match &cfg.tcp {
            Some(addr) => Some(bind_tcp(addr)?),
            None => None,
        };
        let tcp_addr = tcp.as_ref().and_then(tcp_local_addr);
        crate::obs::metrics::mark_server_start();
        let cache = Arc::new(crate::serve::cache::SnapshotCache::new(cfg.cache_budget));
        let sched = Scheduler::start(session, cache.clone(), &cfg);
        Ok(Server {
            uds,
            tcp,
            tcp_addr,
            cfg,
            sched,
            cache,
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(0),
        })
    }

    /// The bound configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The actual TCP listen address, when a TCP listener is bound
    /// (resolves `:0` to the kernel-assigned port).
    pub fn tcp_addr(&self) -> Option<SocketAddr> {
        self.tcp_addr
    }

    /// Current server-wide statistics.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            cache: self.cache.stats(),
            jobs: self.sched.stats(),
        }
    }

    /// Accept clients on every listener until a `SHUTDOWN` frame
    /// arrives, then disconnect remaining clients, drain the scheduler
    /// (queued and running jobs complete) and return. Transient `accept`
    /// failures (e.g. fd exhaustion under many clients) are retried,
    /// never fatal.
    pub fn run(&self) -> Result<()> {
        std::thread::scope(|scope| {
            let uds = scope.spawn(move || self.accept_loop(scope, &self.uds));
            let tcp = self
                .tcp
                .as_ref()
                .map(|listener| scope.spawn(move || self.accept_loop(scope, listener)));
            // Cleanup may only run once *every* acceptor has exited —
            // otherwise a connection accepted during shutdown could slip
            // into the table after it was drained and park its handler
            // (and the scope join) forever.
            let _ = uds.join();
            if let Some(handle) = tcp {
                let _ = handle.join();
            }
            // Refuse new connects fast (path gone beats a backlog hang),
            // then unblock handlers parked on idle clients so the scope
            // can join them.
            let _ = std::fs::remove_file(&self.cfg.socket);
            let remaining: Vec<Conn> = self
                .conns
                .lock()
                .unwrap()
                .drain()
                .map(|(_, conn)| conn)
                .collect();
            for conn in remaining {
                let _ = conn.shutdown();
            }
        });
        self.sched.shutdown();
        Ok(())
    }

    /// One listener's accept loop; handler threads spawn onto `scope`.
    fn accept_loop<'scope>(
        &'scope self,
        scope: &'scope std::thread::Scope<'scope, '_>,
        listener: &'scope Listener,
    ) {
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            let conn = match listener.accept() {
                Ok(conn) => conn,
                Err(_) if self.stop.load(Ordering::SeqCst) => return,
                Err(_) => {
                    // Transient (EMFILE, EINTR, ...): back off briefly
                    // and keep serving instead of killing the server.
                    std::thread::sleep(Duration::from_millis(20));
                    continue;
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                return; // the shutdown waker, or a late connection
            }
            // Per-connection I/O deadlines: an idle-past-timeout or wedged
            // peer surfaces as an I/O error in its handler, which exits
            // and frees the thread — a stalled client can never pin a
            // handler (or a streamed result) forever. Failure to set the
            // options is not worth refusing the connection over.
            let _ = conn.set_timeouts(self.cfg.read_timeout, self.cfg.write_timeout);
            let id = self.next_conn.fetch_add(1, Ordering::SeqCst);
            match conn.try_clone() {
                Ok(clone) => {
                    self.conns.lock().unwrap().insert(id, clone);
                }
                // Without a tracked clone, shutdown could never unblock
                // this handler and run() would hang on the scope join;
                // refuse the connection instead (fd exhaustion — the
                // peer sees a disconnect and retries).
                Err(_) => continue,
            }
            scope.spawn(move || {
                // A handler error (protocol violation, broken pipe)
                // poisons only its own connection.
                let _ = self.handle_connection(conn);
                self.conns.lock().unwrap().remove(&id);
            });
        }
    }

    /// Wake every acceptor parked in `accept` so it observes the stop
    /// flag.
    fn wake_acceptors(&self) {
        self.uds.wake();
        if let Some(tcp) = &self.tcp {
            tcp.wake();
        }
    }

    /// Validate a HELLO token against the configured preshared token.
    fn check_token(&self, presented: &[u8]) -> Result<()> {
        match &self.cfg.token {
            // No token configured (UDS-only server): HELLO is a no-op
            // courtesy, never a gate.
            None => Ok(()),
            Some(expected) => {
                if crate::serve::transport::token_matches(presented, expected.as_bytes()) {
                    Ok(())
                } else {
                    Err(UniGpsError::auth("bad token"))
                }
            }
        }
    }

    /// Serve one client connection until EOF, a failed handshake, or
    /// `SHUTDOWN`.
    fn handle_connection(&self, conn: Conn) -> Result<()> {
        // TCP peers are untrusted until HELLO succeeds; Unix-socket peers
        // are pre-authorized by the socket file's permissions.
        let mut authed = !conn.is_tcp();
        let mut reader = BufReader::new(conn.try_clone()?);
        let mut writer = BufWriter::new(conn);
        loop {
            let (m, payload) = match read_frame(&mut reader) {
                Ok(f) => f,
                Err(UniGpsError::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                    return Ok(()); // peer closed
                }
                Err(e) => return Err(e),
            };
            // RPC latency is measured from frame-decoded to response
            // flushed into the writer, per method — the server-side half
            // of every round trip a client observes.
            let rpc_timer = Timer::start();
            if m == method::HELLO {
                match self.check_token(&payload) {
                    Ok(()) => {
                        authed = true;
                        write_frame(&mut writer, status::OK, &[])?;
                        observe_rpc(m, &rpc_timer);
                        continue;
                    }
                    Err(e) => {
                        // One typed rejection, then the connection dies —
                        // an unauthenticated peer gets no second frame.
                        crate::obs::metrics::registry().transport_auth_failures.inc();
                        write_frame(&mut writer, status::ERR, &encode_error(&e))?;
                        observe_rpc(m, &rpc_timer);
                        return Ok(());
                    }
                }
            }
            if !authed {
                let e = UniGpsError::auth(
                    "authentication required: the first frame on TCP must be HELLO <token>",
                );
                crate::obs::metrics::registry().transport_auth_failures.inc();
                write_frame(&mut writer, status::ERR, &encode_error(&e))?;
                return Ok(());
            }
            if m == method::RESULT {
                // Results stream in chunks — no single frame ever past
                // the cap, and nothing past the client's stream cap: a
                // table the protocol requires every client to refuse is
                // answered with a typed ERR *before* RESULT_BEGIN, never
                // half-streamed.
                let mut pos = 0;
                let outcome = get_u64(&payload, &mut pos).and_then(|id| self.sched.result(id));
                match outcome.map(|result| encode_result(&result)) {
                    Ok(table) if table.len() > MAX_RESULT_LEN => {
                        let e = UniGpsError::serve(format!(
                            "result table is {} bytes, over the {MAX_RESULT_LEN}-byte \
                             stream cap; narrow the result with post-ops (select/top-k)",
                            table.len()
                        ));
                        write_frame(&mut writer, status::ERR, &encode_error(&e))?
                    }
                    Ok(table) => write_result_stream(&mut writer, &table, self.cfg.chunk_len)?,
                    Err(e) => write_frame(&mut writer, status::ERR, &encode_error(&e))?,
                }
                observe_rpc(m, &rpc_timer);
                continue;
            }
            match self.dispatch(m, &payload) {
                // A response over MAX_FRAME_LEN is refused by write_frame
                // *before* any bytes hit the stream, so the connection is
                // still cleanly framed — surface a typed error instead of
                // dropping the client on a raw EOF. (Post-streaming this
                // can only be a pathological status/stats frame.)
                Ok(resp) => match write_frame(&mut writer, status::OK, &resp) {
                    Err(UniGpsError::Ipc(msg)) => {
                        let e = UniGpsError::ipc(format!(
                            "response too large for one frame: {msg}"
                        ));
                        write_frame(&mut writer, status::ERR, &encode_error(&e))?
                    }
                    other => other?,
                },
                Err(e) => write_frame(&mut writer, status::ERR, &encode_error(&e))?,
            }
            observe_rpc(m, &rpc_timer);
            if m == method::SHUTDOWN {
                self.stop.store(true, Ordering::SeqCst);
                self.wake_acceptors();
                return Ok(());
            }
        }
    }

    fn dispatch(&self, m: u32, payload: &[u8]) -> Result<Vec<u8>> {
        match m {
            method::SUBMIT => {
                let spec = std::str::from_utf8(payload)
                    .map_err(|_| UniGpsError::ipc("submit payload is not UTF-8"))?;
                let id = self.sched.submit(spec)?;
                let mut out = Vec::new();
                put_u64(&mut out, id);
                Ok(out)
            }
            method::SUBMIT_PLAN => {
                let plan = decode_plan(payload)?;
                let id = self.sched.submit_plan(plan)?;
                let mut out = Vec::new();
                put_u64(&mut out, id);
                Ok(out)
            }
            method::STATUS => {
                let mut pos = 0;
                let id = get_u64(payload, &mut pos)?;
                Ok(self.sched.status(id)?.encode())
            }
            method::WAIT => {
                let mut pos = 0;
                let id = get_u64(payload, &mut pos)?;
                let ms = get_u64(payload, &mut pos)?.min(MAX_WAIT_SLICE_MS);
                // Park on the completion condvar in short slices so a
                // handler blocked here re-checks the stop flag: server
                // shutdown is never stalled behind a long WAIT (the old
                // poll loop's one virtue, kept at condvar prices).
                let deadline = Instant::now() + Duration::from_millis(ms);
                loop {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    let slice = remaining.min(Duration::from_millis(STOP_CHECK_MS));
                    let st = self.sched.wait_terminal(id, slice)?;
                    if st.state.is_terminal()
                        || remaining <= slice
                        || self.stop.load(Ordering::SeqCst)
                    {
                        return Ok(st.encode());
                    }
                }
            }
            method::CANCEL => {
                let mut pos = 0;
                let id = get_u64(payload, &mut pos)?;
                Ok(self.sched.cancel(id, "client cancel")?.encode())
            }
            method::INGEST => {
                let text = std::str::from_utf8(payload)
                    .map_err(|_| UniGpsError::ipc("ingest payload is not UTF-8"))?;
                Ok(self.sched.ingest(text)?.encode())
            }
            method::STATS => Ok(self.stats().encode()),
            method::METRICS => Ok(crate::obs::metrics::snapshot().encode()),
            method::SHUTDOWN => Ok(Vec::new()),
            other => Err(UniGpsError::Ipc(format!("unknown serve method {other}"))),
        }
    }
}

/// Record one served frame on its method's RPC latency histogram.
/// Sub-microsecond handlers record nothing — the histograms stay
/// observation-only, so a snapshot never invents load.
fn observe_rpc(method: u32, timer: &Timer) {
    if let Some(hist) = crate::obs::metrics::rpc_hist_for(method) {
        let us = timer.elapsed().as_micros() as u64;
        if us > 0 {
            hist.observe_us(us);
        }
    }
}

impl std::fmt::Debug for Server {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Server")
            .field("cfg", &self.cfg)
            .field("tcp_addr", &self.tcp_addr)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_roundtrip() {
        let s = ServeStats {
            cache: CacheStats {
                loads: 1,
                hits: 11,
                misses: 1,
                derived_loads: 2,
                derived_hits: 9,
                derived_misses: 2,
                evictions: 0,
                invalidated: 5,
                resident: 3,
                resident_bytes: 123_456,
                mapped_resident: 2,
                mapped_resident_bytes: 9_876_543,
            },
            jobs: SchedStats {
                submitted: 12,
                rejected: 2,
                completed: 11,
                failed: 1,
                cancelled: 4,
                queued: 3,
                running: 2,
            },
        };
        assert_eq!(ServeStats::decode(&s.encode()).unwrap(), s);
        assert!(ServeStats::decode(&[0u8; 11]).is_err());
        // Back-compat: a 16-word frame from a pre-generation server
        // decodes with every trailing counter defaulting to 0.
        let full = s.encode();
        let decoded = ServeStats::decode(&full[..16 * 8]).unwrap();
        assert_eq!(decoded.cache.invalidated, 0);
        assert_eq!(decoded.cache.mapped_resident, 0);
        assert_eq!(decoded.cache.mapped_resident_bytes, 0);
        assert_eq!(decoded.jobs, s.jobs);
        // A 17-word frame (invalidated, no mapped words) also decodes.
        let decoded = ServeStats::decode(&full[..17 * 8]).unwrap();
        assert_eq!(decoded.cache.invalidated, 5);
        assert_eq!(decoded.cache.mapped_resident, 0);
    }

    #[test]
    fn tcp_without_token_refused_at_bind() {
        let mut cfg = ServeConfig::new(crate::ipc::shm::ShmMap::unique_path("srv-notok"));
        cfg.tcp = Some("127.0.0.1:0".into());
        cfg.token = None;
        let err = Server::bind(Session::builder().build(), cfg).unwrap_err();
        assert!(matches!(err, UniGpsError::Config(_)), "{err:?}");
        assert!(err.to_string().contains("token"), "{err}");
        // An empty token is as unauthenticated as none.
        let mut cfg = ServeConfig::new(crate::ipc::shm::ShmMap::unique_path("srv-emptok"));
        cfg.tcp = Some("127.0.0.1:0".into());
        cfg.token = Some(String::new());
        assert!(Server::bind(Session::builder().build(), cfg).is_err());
    }
}
