//! Connection transports and the serve wire protocol's stream layer.
//!
//! The serving protocol is transport-agnostic: every frame is the
//! hardened `u32 head | u32 len | payload` framing of
//! [`crate::ipc::socket_rpc`] (payloads over
//! [`MAX_FRAME_LEN`](crate::ipc::socket_rpc::MAX_FRAME_LEN) rejected
//! before allocation, on read *and* write), and this module supplies the
//! two byte streams it runs over plus the protocol pieces that sit
//! directly on the framing:
//!
//! * [`Transport`] — the client-side connection factory
//!   [`RemoteClient`](crate::serve::client::RemoteClient) is generic
//!   over: [`UdsTransport`] (Unix-domain socket, authorised by file
//!   permissions) and [`TcpTransport`] (remote clients; performs the
//!   mandatory preshared-token HELLO handshake before handing the
//!   connection out, so every `RemoteClient` method runs on an
//!   authenticated stream).
//! * [`Conn`] / [`Listener`] — the stream and acceptor pair the server
//!   side uses, one variant per transport, `Read + Write` plus the
//!   `try_clone`/`shutdown` surface both the handler table and the
//!   buffered reader/writer split need.
//! * [`reply`] — response head codes: `OK`/`ERR` plus the chunked-result
//!   stream (`RESULT_BEGIN` → `RESULT_CHUNK`* → `RESULT_END`).
//! * [`write_result_stream`] / [`read_result_stream`] — the chunked
//!   result codec. A result table of any size crosses the wire as a
//!   `RESULT_BEGIN` frame declaring the total length and chunk count,
//!   `chunk_count` payload chunks each within the frame cap, and a
//!   `RESULT_END` frame carrying an FNV-1a checksum — so the old
//!   single-frame ceiling (tables over `MAX_FRAME_LEN` answered with a
//!   typed ERR) is gone, while a hostile peer still cannot force an
//!   oversized allocation: the declared total is capped by
//!   [`MAX_RESULT_LEN`], every chunk is length-checked before
//!   allocation, and reassembly verifies count, length and checksum.
//! * [`encode_error`] / [`decode_error`] — the kind-tagged ERR payload
//!   (`u32 error-kind | message`), so clients rebuild the exact
//!   [`UniGpsError`] variant the server raised, auth failures included.

use crate::error::{ErrorKind, Result, UniGpsError};
use crate::ipc::protocol::{get_u32, get_u64, put_u32, put_u64};
use crate::ipc::socket_rpc::{connect_with_retry, read_frame, write_frame, MAX_FRAME_LEN};
use crate::util::fault;
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Response head codes for serve-protocol frames (the `u32 head` of the
/// framing, server → client direction).
pub mod reply {
    /// Success; payload is the method's response encoding.
    pub const OK: u32 = crate::ipc::protocol::status::OK;
    /// Typed failure; payload is `u32 error-kind | message`
    /// ([`super::encode_error`]).
    pub const ERR: u32 = crate::ipc::protocol::status::ERR;
    /// First frame of a chunked result stream: `u64 total_len | u32
    /// chunk_count`.
    pub const RESULT_BEGIN: u32 = 2;
    /// One chunk of result-table bytes (every chunk within the frame cap).
    pub const RESULT_CHUNK: u32 = 3;
    /// Last frame of a result stream: `u64 fnv1a64(table bytes)`.
    pub const RESULT_END: u32 = 4;
}

/// Hard cap on a chunked result table's *total* reassembled size (1 GiB).
/// Each chunk is already capped at the frame limit; this bounds what a
/// hostile `RESULT_BEGIN` header can make a client commit to.
pub const MAX_RESULT_LEN: usize = 1 << 30;

/// Default per-chunk payload size for result streaming (4 MiB — far under
/// the frame cap, so a single slow chunk never monopolizes the stream).
pub const DEFAULT_CHUNK_LEN: usize = 4 << 20;

/// Encode a typed error for an ERR frame: `u32 kind code | UTF-8 message`.
pub fn encode_error(e: &UniGpsError) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, e.kind().code());
    out.extend_from_slice(e.message().as_bytes());
    out
}

/// Decode an ERR frame payload back into the typed error it carried.
/// Malformed payloads degrade to [`UniGpsError::Ipc`], never a panic.
pub fn decode_error(payload: &[u8]) -> UniGpsError {
    let mut pos = 0;
    match get_u32(payload, &mut pos) {
        Ok(code) => ErrorKind::from_code(code)
            .rebuild(String::from_utf8_lossy(&payload[pos..]).into_owned()),
        Err(_) => UniGpsError::ipc(format!(
            "malformed ERR frame: {}",
            String::from_utf8_lossy(payload)
        )),
    }
}

/// FNV-1a over the reassembled table bytes — the `RESULT_END` integrity
/// check. Not cryptographic; it catches reordered/dropped chunks and
/// framing bugs, not adversaries (the token handshake gates those).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stream an encoded result table as `RESULT_BEGIN | RESULT_CHUNK* |
/// RESULT_END`. Works for any `payload` size — this is what lifted the
/// single-frame `MAX_FRAME_LEN` ceiling on result tables. `chunk_len` is
/// clamped into `1..=MAX_FRAME_LEN`.
pub fn write_result_stream(w: &mut impl Write, payload: &[u8], chunk_len: usize) -> Result<()> {
    let chunk_len = chunk_len.clamp(1, MAX_FRAME_LEN);
    let chunks = payload.chunks(chunk_len);
    let mut begin = Vec::with_capacity(12);
    put_u64(&mut begin, payload.len() as u64);
    put_u32(&mut begin, chunks.len() as u32);
    write_frame(w, reply::RESULT_BEGIN, &begin)?;
    for chunk in chunks {
        // Chaos harness: a mid-stream failure here exercises the client's
        // stream-poisoning path (leftover chunks must never be misread as
        // the next response).
        if let Some(act) = fault::point!("result-stream") {
            act.apply("result-stream")?;
        }
        write_frame(w, reply::RESULT_CHUNK, chunk)?;
        crate::obs::metrics::registry()
            .result_chunk_bytes
            .add(chunk.len() as u64);
    }
    let mut end = Vec::with_capacity(8);
    put_u64(&mut end, fnv1a64(payload));
    write_frame(w, reply::RESULT_END, &end)
}

/// How much of a declared stream total is pre-reserved before any chunk
/// arrives (16 MiB). The rest is committed only as chunks actually land,
/// so a forged `RESULT_BEGIN` cannot reserve [`MAX_RESULT_LEN`] up front.
const STREAM_PREALLOC_CAP: usize = 16 << 20;

/// Read one result-stream reply where the `RESULT_BEGIN` frame has
/// already been consumed (its payload is `begin`). Enforces: declared
/// total within [`MAX_RESULT_LEN`], every chunk within the frame cap
/// (via [`read_frame`]), cumulative length never past the declared
/// total, chunk count and checksum exact. A typed ERR frame mid-stream
/// aborts with the carried error.
pub fn read_result_stream_body(r: &mut impl Read, begin: &[u8]) -> Result<Vec<u8>> {
    let mut pos = 0;
    let total = get_u64(begin, &mut pos)? as usize;
    let declared_chunks = get_u32(begin, &mut pos)? as usize;
    if total > MAX_RESULT_LEN {
        return Err(UniGpsError::ipc(format!(
            "result stream declares {total} bytes, over the {MAX_RESULT_LEN} cap; \
             rejecting before allocation"
        )));
    }
    let mut table = Vec::with_capacity(total.min(STREAM_PREALLOC_CAP));
    let mut chunks_seen = 0usize;
    loop {
        let (head, payload) = read_frame(r)?;
        match head {
            reply::RESULT_CHUNK => {
                chunks_seen += 1;
                if chunks_seen > declared_chunks || table.len() + payload.len() > total {
                    return Err(UniGpsError::ipc(format!(
                        "result stream overflow: chunk {chunks_seen} of {declared_chunks} \
                         pushes past the declared {total} bytes"
                    )));
                }
                table.extend_from_slice(&payload);
            }
            reply::RESULT_END => {
                if chunks_seen != declared_chunks || table.len() != total {
                    return Err(UniGpsError::ipc(format!(
                        "result stream truncated: {chunks_seen}/{declared_chunks} chunks, \
                         {}/{total} bytes at RESULT_END",
                        table.len()
                    )));
                }
                let mut pos = 0;
                let want = get_u64(&payload, &mut pos)?;
                let got = fnv1a64(&table);
                if want != got {
                    return Err(UniGpsError::ipc(format!(
                        "result stream checksum mismatch: declared {want:#x}, \
                         reassembled {got:#x}"
                    )));
                }
                return Ok(table);
            }
            reply::ERR => return Err(decode_error(&payload)),
            other => {
                return Err(UniGpsError::ipc(format!(
                    "unexpected head {other} inside a result stream"
                )))
            }
        }
    }
}

/// Read a full result reply: either a typed ERR frame or a
/// `RESULT_BEGIN`-led chunk stream ([`read_result_stream_body`]).
pub fn read_result_stream(r: &mut impl Read) -> Result<Vec<u8>> {
    let (head, payload) = read_frame(r)?;
    match head {
        reply::RESULT_BEGIN => read_result_stream_body(r, &payload),
        reply::ERR => Err(decode_error(&payload)),
        other => Err(UniGpsError::ipc(format!(
            "expected RESULT_BEGIN or ERR, got head {other}"
        ))),
    }
}

/// Constant-time-ish token comparison: every byte of the longer input is
/// examined regardless of where the first mismatch sits, so response
/// timing does not leak a prefix match.
pub fn token_matches(presented: &[u8], expected: &[u8]) -> bool {
    let n = presented.len().max(expected.len());
    let mut diff = presented.len() ^ expected.len();
    for i in 0..n {
        let a = presented.get(i).copied().unwrap_or(0);
        let b = expected.get(i).copied().unwrap_or(0);
        diff |= usize::from(a ^ b);
    }
    diff == 0
}

/// A connected serve-protocol byte stream, one variant per transport.
#[derive(Debug)]
pub enum Conn {
    /// Unix-domain socket stream.
    Unix(UnixStream),
    /// TCP stream (always post-handshake on the client side).
    Tcp(TcpStream),
}

impl Conn {
    /// Clone the underlying socket (split buffered reader/writer halves,
    /// or the server's shutdown table).
    pub fn try_clone(&self) -> Result<Conn> {
        Ok(match self {
            Conn::Unix(s) => Conn::Unix(s.try_clone()?),
            Conn::Tcp(s) => Conn::Tcp(s.try_clone()?),
        })
    }

    /// Shut down both directions, unblocking any thread parked in a read.
    pub fn shutdown(&self) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => s.shutdown(std::net::Shutdown::Both),
            Conn::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
        }
    }

    /// True for connections that arrived over TCP (and therefore must
    /// authenticate before any other method).
    pub fn is_tcp(&self) -> bool {
        matches!(self, Conn::Tcp(_))
    }

    /// Apply per-direction socket timeouts (`None` disables that
    /// direction). The server sets these on every accepted connection
    /// from [`ServeConfig`](crate::serve::ServeConfig) so an idle or
    /// wedged peer releases its handler thread; hardened clients set
    /// their own so a dead server surfaces as a timeout, not a hang.
    pub fn set_timeouts(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)
            }
            Conn::Tcp(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if let Some(act) = fault::point!("transport-read") {
            act.apply_io("transport-read")?;
        }
        let n = match self {
            Conn::Unix(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }?;
        if n > 0 {
            crate::obs::metrics::registry().transport_bytes_read.add(n as u64);
        }
        Ok(n)
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if let Some(act) = fault::point!("transport-write") {
            act.apply_io("transport-write")?;
        }
        let n = match self {
            Conn::Unix(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }?;
        if n > 0 {
            crate::obs::metrics::registry().transport_bytes_written.add(n as u64);
        }
        Ok(n)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

/// A bound serve-protocol acceptor, one variant per transport.
#[derive(Debug)]
pub enum Listener {
    /// Unix-domain socket listener.
    Unix(UnixListener),
    /// TCP listener.
    Tcp(TcpListener),
}

impl Listener {
    /// Accept one connection.
    pub fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                Conn::Tcp(s)
            }),
        }
    }

    /// Connect to this listener from the same process — the shutdown
    /// waker, so an acceptor parked in [`Listener::accept`] observes the
    /// stop flag.
    pub fn wake(&self) {
        match self {
            Listener::Unix(l) => {
                if let Ok(addr) = l.local_addr() {
                    if let Some(path) = addr.as_pathname() {
                        let _ = UnixStream::connect(path);
                    }
                }
            }
            Listener::Tcp(l) => {
                if let Ok(mut addr) = l.local_addr() {
                    // A wildcard bind (0.0.0.0 / ::) is not a connectable
                    // destination everywhere; wake via loopback on the
                    // bound port, and never hang the waker itself.
                    if addr.ip().is_unspecified() {
                        addr.set_ip(match addr.ip() {
                            IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                            IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                        });
                    }
                    // The self-connect *is* the wake; silently ignoring a
                    // failed one (loopback filtered, exhausted backlog)
                    // used to leave the acceptor parked forever. Retry
                    // once, then degrade: flip the listener nonblocking so
                    // every accept from here on returns immediately and
                    // the accept loop's error path polls the stop flag —
                    // slower shutdown, never a hang — and log it.
                    for attempt in 0..2 {
                        if TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_ok() {
                            return;
                        }
                        if attempt == 0 {
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    }
                    let _ = l.set_nonblocking(true);
                    eprintln!(
                        "unigps-serve: tcp shutdown wake to {addr} failed twice; \
                         degrading to stop-flag polling on the accept loop"
                    );
                }
            }
        }
    }
}

/// Client-side connection factory. Implementations return a stream that
/// is ready for serve-protocol frames — for TCP that means the HELLO
/// handshake has already succeeded, so
/// [`RemoteClient`](crate::serve::client::RemoteClient) never sees an
/// unauthenticated connection.
pub trait Transport {
    /// Establish (and, where the transport requires it, authenticate) a
    /// connection.
    fn connect(&self) -> Result<Conn>;
    /// Human-readable endpoint description for error messages.
    fn describe(&self) -> String;
}

/// Unix-domain-socket transport. Authorization is the socket file's
/// permissions; no handshake is performed.
#[derive(Debug, Clone)]
pub struct UdsTransport {
    path: PathBuf,
}

impl UdsTransport {
    /// Transport for the server socket at `path`.
    pub fn new(path: impl Into<PathBuf>) -> UdsTransport {
        UdsTransport { path: path.into() }
    }
}

impl Transport for UdsTransport {
    fn connect(&self) -> Result<Conn> {
        if let Some(act) = fault::point!("transport-connect") {
            act.apply("transport-connect")?;
        }
        let conn = Conn::Unix(connect_with_retry(&self.path)?);
        crate::obs::metrics::registry().transport_connects.inc();
        Ok(conn)
    }
    fn describe(&self) -> String {
        format!("uds://{}", self.path.display())
    }
}

/// TCP transport with the mandatory preshared-token HELLO handshake:
/// `connect` writes a `HELLO` frame carrying the token and requires an
/// `OK` reply before returning the stream. A bad token comes back as the
/// typed [`UniGpsError::Auth`] the server put on the wire.
#[derive(Debug, Clone)]
pub struct TcpTransport {
    addr: String,
    token: String,
}

impl TcpTransport {
    /// Transport for the server at `addr` (`host:port`) authenticating
    /// with `token`.
    pub fn new(addr: impl Into<String>, token: impl Into<String>) -> TcpTransport {
        TcpTransport {
            addr: addr.into(),
            token: token.into(),
        }
    }
}

impl Transport for TcpTransport {
    fn connect(&self) -> Result<Conn> {
        if let Some(act) = fault::point!("transport-connect") {
            act.apply("transport-connect")?;
        }
        // Same startup-retry envelope as the Unix transport's
        // connect_with_retry (200 × 5 ms), so both transports behind the
        // one Client trait tolerate a just-starting server equally.
        let mut last_err = None;
        let mut stream = None;
        for _ in 0..200 {
            match TcpStream::connect(&self.addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
        let stream = stream.ok_or_else(|| {
            UniGpsError::ipc(format!("connect({}) failed: {last_err:?}", self.describe()))
        })?;
        let _ = stream.set_nodelay(true);
        let mut conn = Conn::Tcp(stream);
        write_frame(&mut conn, crate::serve::method::HELLO, self.token.as_bytes())?;
        let (head, payload) = read_frame(&mut conn)?;
        match head {
            reply::OK => {
                crate::obs::metrics::registry().transport_connects.inc();
                Ok(conn)
            }
            reply::ERR => Err(decode_error(&payload)),
            other => Err(UniGpsError::ipc(format!(
                "bad HELLO reply head {other} from {}",
                self.describe()
            ))),
        }
    }
    fn describe(&self) -> String {
        format!("tcp://{}", self.addr)
    }
}

/// Parse a `--connect` style endpoint: `tcp://host:port` (token required,
/// supplied separately), `uds://<path>`, or a bare filesystem path
/// (treated as a Unix socket). Returns `(tcp_addr, uds_path)` with
/// exactly one side populated.
pub fn parse_endpoint(uri: &str) -> Result<(Option<String>, Option<PathBuf>)> {
    if let Some(addr) = uri.strip_prefix("tcp://") {
        if addr.is_empty() {
            return Err(UniGpsError::Config("tcp:// endpoint needs host:port".into()));
        }
        Ok((Some(addr.to_string()), None))
    } else if let Some(path) = uri.strip_prefix("uds://") {
        if path.is_empty() {
            return Err(UniGpsError::Config("uds:// endpoint needs a path".into()));
        }
        Ok((None, Some(PathBuf::from(path))))
    } else if uri.contains("://") {
        Err(UniGpsError::Config(format!(
            "unknown endpoint scheme in '{uri}' (tcp://host:port or uds:///path)"
        )))
    } else {
        Ok((None, Some(PathBuf::from(uri))))
    }
}

/// Bind the Unix listener for a serve instance, replacing a stale socket
/// file.
pub fn bind_uds(path: &Path) -> Result<Listener> {
    let _ = std::fs::remove_file(path);
    Ok(Listener::Unix(UnixListener::bind(path)?))
}

/// Bind the TCP listener for a serve instance. `addr` may use port 0;
/// the actual bound address is retrievable via [`Listener`]'s inner
/// `local_addr` (exposed as [`tcp_local_addr`]).
pub fn bind_tcp(addr: &str) -> Result<Listener> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| UniGpsError::ipc(format!("bind(tcp://{addr}) failed: {e}")))?;
    Ok(Listener::Tcp(listener))
}

/// The bound address of a TCP [`Listener`] (`None` for Unix listeners).
pub fn tcp_local_addr(listener: &Listener) -> Option<SocketAddr> {
    match listener {
        Listener::Tcp(l) => l.local_addr().ok(),
        Listener::Unix(_) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_roundtrip_small_and_empty() {
        for (len, chunk) in [(0usize, 16usize), (1, 16), (16, 16), (17, 16), (4096, 1)] {
            let payload: Vec<u8> = (0..len).map(|i| (i * 31 % 251) as u8).collect();
            let mut wire: Vec<u8> = Vec::new();
            write_result_stream(&mut wire, &payload, chunk).unwrap();
            let back = read_result_stream(&mut wire.as_slice()).unwrap();
            assert_eq!(back, payload, "len={len} chunk={chunk}");
        }
    }

    #[test]
    fn stream_rejects_forged_total_before_allocation() {
        let mut begin = Vec::new();
        put_u64(&mut begin, (MAX_RESULT_LEN as u64) + 1);
        put_u32(&mut begin, 1);
        let mut wire: Vec<u8> = Vec::new();
        write_frame(&mut wire, reply::RESULT_BEGIN, &begin).unwrap();
        let err = read_result_stream(&mut wire.as_slice()).unwrap_err();
        assert!(matches!(err, UniGpsError::Ipc(_)), "{err:?}");
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn stream_rejects_checksum_and_count_mismatches() {
        let payload = vec![9u8; 100];
        // Corrupt one chunk byte: checksum must catch it.
        let mut wire: Vec<u8> = Vec::new();
        write_result_stream(&mut wire, &payload, 32).unwrap();
        // Frame layout: BEGIN(8+12) then chunk frames; flip a byte inside
        // the first chunk's payload (after its 8-byte frame header).
        let first_chunk_payload = 8 + 12 + 8;
        wire[first_chunk_payload] ^= 0xFF;
        let err = read_result_stream(&mut wire.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // A missing chunk (count mismatch) is caught at RESULT_END.
        let mut wire: Vec<u8> = Vec::new();
        let mut begin = Vec::new();
        put_u64(&mut begin, 64);
        put_u32(&mut begin, 2);
        write_frame(&mut wire, reply::RESULT_BEGIN, &begin).unwrap();
        write_frame(&mut wire, reply::RESULT_CHUNK, &[1u8; 32]).unwrap();
        let mut end = Vec::new();
        put_u64(&mut end, fnv1a64(&[1u8; 32]));
        write_frame(&mut wire, reply::RESULT_END, &end).unwrap();
        let err = read_result_stream(&mut wire.as_slice()).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // Chunks past the declared total are an overflow, typed.
        let mut wire: Vec<u8> = Vec::new();
        let mut begin = Vec::new();
        put_u64(&mut begin, 16);
        put_u32(&mut begin, 1);
        write_frame(&mut wire, reply::RESULT_BEGIN, &begin).unwrap();
        write_frame(&mut wire, reply::RESULT_CHUNK, &[0u8; 64]).unwrap();
        let err = read_result_stream(&mut wire.as_slice()).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
    }

    #[test]
    fn stream_propagates_typed_errors_midstream() {
        // A server that fails while streaming sends a typed ERR frame;
        // the reader surfaces the exact variant, not a framing error.
        let e = UniGpsError::serve("job 3 evicted mid-fetch");
        let mut wire: Vec<u8> = Vec::new();
        let mut begin = Vec::new();
        put_u64(&mut begin, 64);
        put_u32(&mut begin, 2);
        write_frame(&mut wire, reply::RESULT_BEGIN, &begin).unwrap();
        write_frame(&mut wire, reply::RESULT_CHUNK, &[0u8; 32]).unwrap();
        write_frame(&mut wire, reply::ERR, &encode_error(&e)).unwrap();
        let err = read_result_stream(&mut wire.as_slice()).unwrap_err();
        assert!(matches!(err, UniGpsError::Serve(_)), "{err:?}");
        assert!(err.to_string().contains("evicted"), "{err}");
        // And an up-front ERR (job failed before any chunk) decodes too.
        let mut wire: Vec<u8> = Vec::new();
        write_frame(&mut wire, reply::ERR, &encode_error(&e)).unwrap();
        assert!(matches!(read_result_stream(&mut wire.as_slice()), Err(UniGpsError::Serve(_))));
    }

    #[test]
    fn token_comparison_covers_length_and_content() {
        assert!(token_matches(b"secret", b"secret"));
        assert!(!token_matches(b"secret", b"secret2"));
        assert!(!token_matches(b"", b"secret"));
        assert!(!token_matches(b"Secret", b"secret"));
        assert!(token_matches(b"", b""));
    }

    #[test]
    fn endpoint_parsing() {
        assert_eq!(
            parse_endpoint("tcp://127.0.0.1:7077").unwrap(),
            (Some("127.0.0.1:7077".into()), None)
        );
        assert_eq!(
            parse_endpoint("uds:///tmp/u.sock").unwrap(),
            (None, Some(PathBuf::from("/tmp/u.sock")))
        );
        assert_eq!(
            parse_endpoint("/tmp/u.sock").unwrap(),
            (None, Some(PathBuf::from("/tmp/u.sock")))
        );
        assert!(parse_endpoint("grpc://x").is_err());
        assert!(parse_endpoint("tcp://").is_err());
        assert!(parse_endpoint("uds://").is_err());
    }

    #[test]
    fn error_codec_preserves_the_variant() {
        for e in [
            UniGpsError::backpressure("queue full (64 queued, capacity 64); retry later"),
            UniGpsError::serve("unknown job 9"),
            UniGpsError::auth("bad token"),
            UniGpsError::Config("unknown algo 'warp'".into()),
            UniGpsError::ipc("frame length 999 exceeds limit"),
        ] {
            let back = decode_error(&encode_error(&e));
            assert_eq!(back.kind(), e.kind(), "{e:?}");
            assert_eq!(back.message(), e.message());
        }
        // Truncated/garbage payloads degrade to Ipc.
        assert!(matches!(decode_error(&[1, 2]), UniGpsError::Ipc(_)));
        assert!(matches!(decode_error(b""), UniGpsError::Ipc(_)));
    }
}
