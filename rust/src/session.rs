//! The UniGPS session handle — the paper's `unigps` object (Fig 3).
//!
//! A [`Session`] bundles the default engine, run options and artifact
//! directory, and exposes graph loading/generation plus the processing
//! entry points. Since the plan unification, every convenience method is
//! sugar over the logical-plan IR ([`crate::plan::Plan`]):
//! `session.pagerank(&g)` returns an [`OperatorBuilder`] that lowers to a
//! one-stage plan, and [`Session::run_plan`] / [`Session::run_plan_on`]
//! execute arbitrary multi-stage plans (transforms + stages + post-ops)
//! with this session's settings as the base layer — the same IR the CLI's
//! `run --plan` and the serving job specs execute, so results cannot
//! depend on which surface submitted the work. The session is also the
//! config-plumbing root: [`Session::overlay_config`] layers plan defaults
//! and per-stage overrides exactly like config files and job specs.
//!
//! The generic [`Session::vcprog`] runner remains for bespoke user
//! program types that cannot cross a wire (plans reach registered custom
//! programs via [`crate::plan::StageOp::Custom`]).

use crate::config::Config;
use crate::engine::{self, EngineKind, RunOptions, RunResult};
use crate::error::Result;
use crate::graph::datasets::DatasetSpec;
use crate::graph::generate::{self, WeightKind};
use crate::graph::io::Format;
use crate::graph::Graph;
use crate::operators::{Operator, OperatorBuilder};
use crate::plan::Plan;
use crate::vcprog::{VCProg, VertexId};
use std::path::{Path, PathBuf};

/// A configured UniGPS session.
#[derive(Debug, Clone)]
pub struct Session {
    engine: EngineKind,
    opts: RunOptions,
    artifacts_dir: PathBuf,
}

/// Builder for [`Session`].
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    engine: EngineKind,
    opts: RunOptions,
    artifacts_dir: PathBuf,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            engine: EngineKind::Pregel,
            opts: RunOptions::default(),
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl SessionBuilder {
    /// Default engine for operators without an explicit `engine=`.
    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.engine = kind;
        self
    }

    /// Worker threads.
    pub fn workers(mut self, w: usize) -> Self {
        self.opts.workers = w.max(1);
        self
    }

    /// Artifact directory for the tensor engine.
    pub fn artifacts_dir(mut self, p: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = p.into();
        self
    }

    /// Finish.
    pub fn build(self) -> Session {
        Session {
            engine: self.engine,
            opts: self.opts,
            artifacts_dir: self.artifacts_dir,
        }
    }
}

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Create from a config file — the paper's
    /// `UniGPS.createByHdfsConfFile(...)`.
    pub fn from_config_file(path: &Path) -> Result<Session> {
        let cfg = Config::load(path)?;
        Session::from_config(&cfg)
    }

    /// Create from a parsed [`Config`] (builder defaults for missing keys).
    pub fn from_config(cfg: &Config) -> Result<Session> {
        Session::builder().build().overlay_config(cfg)
    }

    /// Return a copy of this session with any keys present in `cfg`
    /// overriding the current settings; missing keys keep this session's
    /// values. This is the single config-plumbing path: [`Session::from_config`]
    /// layers a config over builder defaults, and the serving subsystem
    /// ([`crate::serve`]) layers each submitted job spec over the server
    /// session the same way.
    pub fn overlay_config(&self, cfg: &Config) -> Result<Session> {
        let engine = match cfg.get("engine") {
            None => self.engine,
            Some(e) => EngineKind::parse(e).ok_or_else(|| {
                crate::error::UniGpsError::Config(format!("unknown engine '{e}'"))
            })?,
        };
        let mut opts = self.opts.clone();
        opts.workers = cfg.get_usize("workers", opts.workers)?.max(1);
        opts.max_iter = cfg.get_usize("max_iter", opts.max_iter as usize)? as u32;
        opts.combiner = cfg.get_bool("combiner", opts.combiner)?;
        opts.pipeline = cfg.get_bool("pipeline", opts.pipeline)?;
        opts.step_metrics = cfg.get_bool("step_metrics", opts.step_metrics)?;
        opts.pushpull_threshold = cfg.get_f64("pushpull_threshold", opts.pushpull_threshold)?;
        if let Some(p) = cfg.get("partition") {
            opts.partition = crate::graph::partition::PartitionStrategy::parse(p)
                .ok_or_else(|| {
                    crate::error::UniGpsError::Config(format!("unknown partition '{p}'"))
                })?;
        }
        let artifacts_dir = match cfg.get("artifacts_dir") {
            None => self.artifacts_dir.clone(),
            Some(p) => PathBuf::from(p),
        };
        Ok(Session {
            engine,
            opts,
            artifacts_dir,
        })
    }

    /// Default engine.
    pub fn default_engine(&self) -> EngineKind {
        self.engine
    }

    /// Default run options.
    pub fn options(&self) -> &RunOptions {
        &self.opts
    }

    /// Artifact directory (tensor engine).
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    // --- graph acquisition --------------------------------------------------

    /// Load a graph, inferring the format from the extension — the paper's
    /// `UniGraph.createByHdfsDir(...)` analog.
    pub fn load(&self, path: &Path) -> Result<Graph> {
        Format::from_path(path).load(path)
    }

    /// Store a graph, inferring the format from the extension.
    pub fn store(&self, graph: &Graph, path: &Path) -> Result<()> {
        Format::from_path(path).store(graph, path)
    }

    /// Generate a synthetic graph: `kind` ∈ {rmat, lognormal, er, grid,
    /// star} (unknown kinds fall back to ER).
    pub fn generate(&self, kind: &str, vertices: usize, edges: usize, seed: u64) -> Graph {
        match kind {
            "rmat" => {
                let scale = vertices.next_power_of_two().trailing_zeros();
                generate::rmat(
                    scale,
                    edges,
                    (0.57, 0.19, 0.19, 0.05),
                    true,
                    WeightKind::UniformInt(64),
                    seed,
                )
            }
            "lognormal" => generate::log_normal(
                vertices,
                1.2,
                1.0,
                true,
                WeightKind::UniformInt(64),
                seed,
            ),
            "grid" => {
                let side = (vertices as f64).sqrt().ceil() as usize;
                generate::grid(side, side, true)
            }
            "star" => generate::star(vertices, true),
            _ => generate::erdos_renyi(vertices, edges, true, WeightKind::UniformInt(64), seed),
        }
    }

    /// Generate a Table II dataset analog by key (`as`, `lj`, `ok`, `uk`).
    pub fn dataset(&self, key: &str, scale_divisor: u64) -> Option<Graph> {
        DatasetSpec::by_key(key).map(|d| d.generate(scale_divisor))
    }

    // --- processing ---------------------------------------------------------

    /// Run a user VCProg program — the paper's `unigps.vcprog(in_graph,
    /// user_program=..., engine=...)`.
    pub fn vcprog<P: VCProg<In = (), EProp = f64>>(
        &self,
        graph: &Graph,
        program: &P,
        engine: Option<EngineKind>,
    ) -> Result<RunResult> {
        engine::run(engine.unwrap_or(self.engine), graph, program, &self.opts)
    }

    /// Execute a multi-stage [`Plan`], materializing its source through
    /// this session (the CLI `run --plan` path). Plan defaults and
    /// per-stage overrides layer over this session's settings.
    pub fn run_plan(&self, plan: &Plan) -> Result<RunResult> {
        plan.run(self)
    }

    /// Execute a [`Plan`] against an already-loaded graph (the plan's
    /// `source`, if any, is ignored).
    pub fn run_plan_on(&self, graph: &Graph, plan: &Plan) -> Result<RunResult> {
        plan.run_on(graph, self)
    }

    /// Native operator: PageRank (20 iterations by default; tune with the
    /// builder).
    pub fn pagerank<'g>(&self, graph: &'g Graph) -> OperatorBuilder<'g> {
        self.op(graph, Operator::PageRank { iterations: 20 })
    }

    /// Native operator: single-source shortest path.
    pub fn sssp<'g>(&self, graph: &'g Graph, root: VertexId) -> OperatorBuilder<'g> {
        self.op(graph, Operator::Sssp { root })
    }

    /// Native operator: connected components.
    pub fn cc<'g>(&self, graph: &'g Graph) -> OperatorBuilder<'g> {
        self.op(graph, Operator::ConnectedComponents)
    }

    /// Native operator: BFS hop distance.
    pub fn bfs<'g>(&self, graph: &'g Graph, root: VertexId) -> OperatorBuilder<'g> {
        self.op(graph, Operator::Bfs { root })
    }

    /// Native operator: degree count.
    pub fn degrees<'g>(&self, graph: &'g Graph) -> OperatorBuilder<'g> {
        self.op(graph, Operator::Degrees)
    }

    /// Native operator: label-propagation communities.
    pub fn lpa<'g>(&self, graph: &'g Graph, iterations: u32) -> OperatorBuilder<'g> {
        self.op(graph, Operator::Lpa { iterations })
    }

    /// Native operator: k-core membership.
    pub fn kcore<'g>(&self, graph: &'g Graph, k: i64) -> OperatorBuilder<'g> {
        self.op(graph, Operator::KCore { k })
    }

    /// Native operator: triangle counting.
    pub fn triangles<'g>(&self, graph: &'g Graph) -> OperatorBuilder<'g> {
        self.op(graph, Operator::Triangles)
    }

    fn op<'g>(&self, graph: &'g Graph, op: Operator) -> OperatorBuilder<'g> {
        // The session rides along as the builder's base layer, so the
        // lowered plan carries only *explicit* overrides — every surface
        // emits the same IR for the same request.
        OperatorBuilder::over(graph, op, self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_from_config() {
        let cfg = Config::parse("engine = gemini\nworkers = 3\ncombiner = off").unwrap();
        let s = Session::from_config(&cfg).unwrap();
        assert_eq!(s.default_engine(), EngineKind::PushPull);
        assert_eq!(s.options().workers, 3);
        assert!(!s.options().combiner);
    }

    #[test]
    fn bad_engine_rejected() {
        let cfg = Config::parse("engine = fortran").unwrap();
        assert!(Session::from_config(&cfg).is_err());
    }

    #[test]
    fn overlay_keeps_base_settings_for_missing_keys() {
        let base = Session::builder()
            .workers(7)
            .engine(EngineKind::Gas)
            .artifacts_dir("custom-artifacts")
            .build();
        let over = base
            .overlay_config(&Config::parse("combiner = on").unwrap())
            .unwrap();
        assert_eq!(over.default_engine(), EngineKind::Gas, "engine kept");
        assert_eq!(over.options().workers, 7, "workers kept");
        assert!(over.options().combiner, "combiner overridden");
        assert_eq!(over.artifacts_dir(), Path::new("custom-artifacts"));
        let over = base
            .overlay_config(&Config::parse("engine = serial\nworkers = 2").unwrap())
            .unwrap();
        assert_eq!(over.default_engine(), EngineKind::Serial);
        assert_eq!(over.options().workers, 2);
        assert!(base
            .overlay_config(&Config::parse("partition = voronoi").unwrap())
            .is_err());
    }

    #[test]
    fn generate_and_run_quickstart() {
        let s = Session::builder().workers(2).build();
        let g = s.generate("rmat", 256, 1024, 7);
        let r = s.pagerank(&g).max_iter(6).run().unwrap();
        let ranks = r.column("rank").unwrap().as_f64().unwrap();
        assert_eq!(ranks.len(), g.num_vertices());
        let top = r.top_k_f64("rank", 3);
        assert_eq!(top.len(), 3);
        assert!(top[0].1 >= top[1].1);
    }

    #[test]
    fn dataset_lookup() {
        let s = Session::builder().build();
        assert!(s.dataset("lj", 4096).is_some());
        assert!(s.dataset("nope", 64).is_none());
    }
}
