//! Read-only file mappings for out-of-core snapshots.
//!
//! [`MapRegion`] maps a whole snapshot file `PROT_READ`/`MAP_SHARED`
//! through the same hand-rolled FFI binding as [`crate::ipc::shm`] (the
//! `libc` crate is not vendored offline). The mapping is immutable and
//! shared by everything that reads through it — the [`Topology`]
//! backing's section slices and the graph's weight column all hold one
//! `Arc<MapRegion>`, so the file is mapped exactly once per load and
//! unmapped when the last reader drops.
//!
//! Mapped bytes live in page cache, not on the process heap: the
//! snapshot cache counts them separately (`CacheStats::mapped_resident_bytes`)
//! and excludes them from the eviction byte budget. The file is assumed
//! immutable while mapped (exactly the contract `DatasetRef::File`
//! already states for cached graphs); truncating a mapped snapshot
//! out from under a reader is undefined at the OS level (SIGBUS), which
//! `docs/storage.md` calls out.
//!
//! Like `ipc::shm`, the binding declares `off_t` as `i64` and is gated to
//! 64-bit targets; 32-bit callers get a clean runtime error. Miri has no
//! mmap support, so the nightly Miri CI job stays scoped past this module
//! (the pure-Rust varint and layout code is covered by the regular suite).
//!
//! [`Topology`]: crate::graph::csr::Topology

use crate::error::{Result, UniGpsError};
use std::path::Path;

#[cfg(target_pointer_width = "64")]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_SHARED: c_int = 1;

    pub fn map_failed() -> *mut c_void {
        -1isize as *mut c_void
    }

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A read-only mapping of an entire snapshot file.
pub struct MapRegion {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is read-only (`PROT_READ`) for its whole lifetime —
// no writer exists, so concurrent reads from any thread are race-free.
unsafe impl Send for MapRegion {}
// SAFETY: as above — immutable bytes are safely shared across threads.
unsafe impl Sync for MapRegion {}

impl MapRegion {
    /// Map `path` read-only in its entirety. Empty files are refused (a
    /// zero-length mmap is EINVAL; no valid snapshot is empty).
    #[cfg(target_pointer_width = "64")]
    pub fn open(path: &Path) -> Result<MapRegion> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            return Err(UniGpsError::Parse(format!("{} is empty", path.display())));
        }
        let len = usize::try_from(len)
            .map_err(|_| UniGpsError::Parse(format!("{} too large to map", path.display())))?;
        // SAFETY: standard read-only mmap of an open, sized file; the
        // failure sentinel is checked below and the fd may close after
        // mmap returns (the mapping keeps its own reference).
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() {
            return Err(UniGpsError::Io(std::io::Error::last_os_error()));
        }
        Ok(MapRegion { ptr: ptr as *const u8, len })
    }

    /// 32-bit stub: same clean error as [`crate::ipc::shm::ShmMap`].
    #[cfg(not(target_pointer_width = "64"))]
    pub fn open(path: &Path) -> Result<MapRegion> {
        Err(UniGpsError::Config(format!(
            "mmap-backed snapshot {} requires a 64-bit target \
             (hand-rolled mmap binding assumes 64-bit off_t)",
            path.display()
        )))
    }

    /// Mapped length in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when zero-length (never for successfully opened regions).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The whole mapping as bytes.
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len come from a successful mmap held alive by
        // `self`; the mapping is read-only and never remapped.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// A typed window at `offset` covering `len` elements. `T` must be a
    /// plain little-endian word type (u32/u64/usize/f64 — the only
    /// instantiations in this crate); the caller (the snapshot loader)
    /// has already verified that `[offset, offset + len*size_of::<T>())`
    /// is in bounds and `offset` is aligned for `T` — both are rechecked
    /// here so a logic slip fails closed instead of reading wild.
    #[inline]
    pub(crate) fn typed_slice<T>(&self, offset: usize, len: usize) -> &[T] {
        let size = std::mem::size_of::<T>();
        let end = offset.checked_add(len.checked_mul(size).expect("section size overflow"));
        assert!(end.is_some_and(|e| e <= self.len), "section window out of bounds");
        assert_eq!(offset % std::mem::align_of::<T>(), 0, "section window misaligned");
        // SAFETY: bounds and alignment asserted above; the bytes are
        // immutable for the mapping's lifetime and every instantiated T
        // is a plain word type valid for any bit pattern.
        unsafe { std::slice::from_raw_parts(self.ptr.add(offset) as *const T, len) }
    }
}

impl Drop for MapRegion {
    fn drop(&mut self) {
        // SAFETY: ptr/len came from the successful mmap in `open` (the
        // only constructor on 64-bit targets; 32-bit never constructs).
        #[cfg(target_pointer_width = "64")]
        unsafe {
            sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
        }
    }
}

impl std::fmt::Debug for MapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MapRegion").field("len", &self.len).finish()
    }
}

/// A zero-copy typed column over a shared [`MapRegion`] — the mapped
/// counterpart of a `Vec<T>` property column. Holding the `Arc` keeps
/// the mapping alive for as long as any graph clone references it.
#[derive(Debug, Clone)]
pub struct MappedSlice<T> {
    region: std::sync::Arc<MapRegion>,
    offset: usize,
    len: usize,
    _marker: std::marker::PhantomData<fn() -> T>,
}

impl<T> MappedSlice<T> {
    /// Wrap a validated section window (bounds/alignment are rechecked
    /// by [`MapRegion::typed_slice`] on every access). `T: Copy` guards
    /// construction: only plain word types may view mapped bytes.
    pub(crate) fn new(region: std::sync::Arc<MapRegion>, offset: usize, len: usize) -> Self
    where
        T: Copy,
    {
        // Fail closed at construction too, not only on first read.
        let _ = region.typed_slice::<T>(offset, len);
        MappedSlice { region, offset, len, _marker: std::marker::PhantomData }
    }

    /// The window as a slice.
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        self.region.typed_slice(self.offset, self.len)
    }

    /// Bytes held by the mapping window (page cache, not heap).
    pub fn mapped_bytes(&self) -> usize {
        self.len * std::mem::size_of::<T>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        crate::graph::io::tmp_path(name)
    }

    #[test]
    fn maps_whole_file_read_only() {
        let p = tmp("map-ro.bin");
        let data: Vec<u8> = (0..8192u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&p, &data).unwrap();
        let region = MapRegion::open(&p).unwrap();
        assert_eq!(region.len(), data.len());
        assert_eq!(region.bytes(), &data[..]);
        let words: &[u64] = region.typed_slice(0, data.len() / 8);
        assert_eq!(words.len(), 1024);
        drop(region);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_empty_and_missing_files() {
        let p = tmp("map-empty.bin");
        std::fs::write(&p, b"").unwrap();
        assert!(MapRegion::open(&p).is_err());
        let _ = std::fs::remove_file(&p);
        assert!(MapRegion::open(&p).is_err());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn typed_slice_rechecks_bounds() {
        let p = tmp("map-oob.bin");
        std::fs::write(&p, vec![0u8; 64]).unwrap();
        let region = MapRegion::open(&p).unwrap();
        let path = p.clone();
        let _guard = scopeguard(move || {
            let _ = std::fs::remove_file(&path);
        });
        let _ = region.typed_slice::<u64>(0, 9);
    }

    fn scopeguard<F: FnMut()>(f: F) -> impl Drop {
        struct G<F: FnMut()>(F);
        impl<F: FnMut()> Drop for G<F> {
            fn drop(&mut self) {
                (self.0)();
            }
        }
        G(f)
    }
}
