//! Out-of-core storage: pluggable [`Topology`] backings + packed snapshots.
//!
//! The paper's headline claim is processing "big graphs beyond the memory
//! capacity of a single machine"; this layer is the repo's out-of-core
//! substrate. A [`Topology`](crate::graph::csr::Topology) no longer owns
//! `Vec`s directly — it reads CSR/CSC through a [`TopologySource`]
//! backing, of which there are three:
//!
//! * [`HeapBacking`] — today's `Vec`-backed arrays. The default for every
//!   builder/generator/loader path; zero behavior or performance change.
//! * [`MmapBacking`] — zero-copy slices over a page-aligned **binfmt v2**
//!   snapshot ([`snapshot`]) mapped read-only via [`mmap::MapRegion`].
//!   The file carries a precomputed CSC mirror, so loading never
//!   materializes anything graph-sized on the heap: the graph's resident
//!   cost is page cache, which the snapshot cache tracks separately from
//!   its heap byte budget.
//! * [`CompressedBacking`] — varint-delta adjacency ([`varint`]) with
//!   per-block skip offsets, for memory-constrained *resident* use.
//!   Offsets stay raw (`out_degree_prefix` keeps its O(1) contract);
//!   target/source/edge-id streams decode through row cursors.
//!
//! All three produce **bit-identical** results through every engine:
//! the compressed encoding is order-preserving (delta from the previous
//! stored value, not a sorted canonical form), so message fold order —
//! and therefore every f64 — matches the heap backing exactly. This is
//! property-tested in `rust/tests/store_backing.rs`.
//!
//! Selection is wired through the stack as `store = heap|mmap|compressed`
//! ([`StoreMode`]) on `DatasetRef` file sources and the `unigps pack`
//! CLI writes the v2 snapshots. See `docs/storage.md`.

pub mod mmap;
pub mod snapshot;
pub mod varint;

use crate::error::{Result, UniGpsError};
use crate::graph::csr::Topology;
use crate::vcprog::VertexId;
pub use mmap::{MapRegion, MappedSlice};
pub use varint::{CompressedSeq, SeqCursor};

/// How a file-sourced graph is held in memory (`store = …` in specs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StoreMode {
    /// Fully heap-resident `Vec` arrays (the historical behavior).
    #[default]
    Heap,
    /// Zero-copy mmap of a binfmt v2 snapshot (page cache, ~0 heap).
    Mmap,
    /// Varint-delta compressed adjacency, heap-resident but small.
    Compressed,
}

impl StoreMode {
    /// Parse a `store =` config value.
    pub fn parse(s: &str) -> Option<StoreMode> {
        match s {
            "heap" => Some(StoreMode::Heap),
            "mmap" => Some(StoreMode::Mmap),
            "compressed" => Some(StoreMode::Compressed),
            _ => None,
        }
    }

    /// The config spelling [`StoreMode::parse`] accepts.
    pub fn as_str(&self) -> &'static str {
        match self {
            StoreMode::Heap => "heap",
            StoreMode::Mmap => "mmap",
            StoreMode::Compressed => "compressed",
        }
    }
}

/// What a backing exposes to [`Topology`](crate::graph::csr::Topology):
/// always-raw offset prefixes (every backing keeps both offset arrays as
/// plain word slices — heap `Vec`, mapped section, or resident `Vec`
/// next to compressed streams) plus the adjacency payload, which is
/// either raw slices or compressed streams ([`Adjacency`]).
pub trait TopologySource {
    /// CSR row offsets, length `num_vertices + 1`.
    fn out_offsets(&self) -> &[usize];
    /// CSC row offsets, length `num_vertices + 1`.
    fn in_offsets(&self) -> &[usize];
    /// The adjacency payload.
    fn adjacency(&self) -> Adjacency<'_>;
    /// Process-heap bytes held by this backing.
    fn heap_bytes(&self) -> usize;
    /// Mapped (page-cache) bytes held by this backing.
    fn mapped_bytes(&self) -> usize;
    /// Which store mode this backing implements.
    fn mode(&self) -> StoreMode;
}

/// Adjacency payload of a backing: raw slices (heap and mmap) or
/// compressed streams decoded through row cursors.
pub enum Adjacency<'a> {
    /// Directly indexable arrays.
    Raw {
        /// CSR edge targets, length `num_edges`.
        out_targets: &'a [VertexId],
        /// CSC edge sources, length `num_edges`.
        in_sources: &'a [VertexId],
        /// CSR edge id of each CSC slot, length `num_edges`.
        in_edge_ids: &'a [usize],
    },
    /// Varint-delta streams (same three arrays, compressed).
    Packed {
        /// CSR edge targets.
        out_targets: &'a CompressedSeq,
        /// CSC edge sources.
        in_sources: &'a CompressedSeq,
        /// CSR edge id of each CSC slot.
        in_edge_ids: &'a CompressedSeq,
    },
}

/// The historical `Vec`-backed arrays (zero-regression default).
#[derive(Debug, Clone)]
pub struct HeapBacking {
    pub(crate) out_offsets: Vec<usize>,
    pub(crate) out_targets: Vec<VertexId>,
    pub(crate) in_offsets: Vec<usize>,
    pub(crate) in_sources: Vec<VertexId>,
    pub(crate) in_edge_ids: Vec<usize>,
}

impl TopologySource for HeapBacking {
    fn out_offsets(&self) -> &[usize] {
        &self.out_offsets
    }
    fn in_offsets(&self) -> &[usize] {
        &self.in_offsets
    }
    fn adjacency(&self) -> Adjacency<'_> {
        Adjacency::Raw {
            out_targets: &self.out_targets,
            in_sources: &self.in_sources,
            in_edge_ids: &self.in_edge_ids,
        }
    }
    fn heap_bytes(&self) -> usize {
        self.out_offsets.len() * 8
            + self.out_targets.len() * 4
            + self.in_offsets.len() * 8
            + self.in_sources.len() * 4
            + self.in_edge_ids.len() * 8
    }
    fn mapped_bytes(&self) -> usize {
        0
    }
    fn mode(&self) -> StoreMode {
        StoreMode::Heap
    }
}

/// Zero-copy slices over a mapped binfmt v2 snapshot. Every array is a
/// window into the shared [`MapRegion`]; nothing graph-sized lives on
/// the heap. Clones share the mapping (`Arc`).
#[derive(Debug, Clone)]
pub struct MmapBacking {
    pub(crate) region: std::sync::Arc<MapRegion>,
    /// `(byte offset, element count)` windows into the region.
    pub(crate) out_offsets: (usize, usize),
    pub(crate) out_targets: (usize, usize),
    pub(crate) in_offsets: (usize, usize),
    pub(crate) in_sources: (usize, usize),
    pub(crate) in_edge_ids: (usize, usize),
}

impl TopologySource for MmapBacking {
    fn out_offsets(&self) -> &[usize] {
        self.region.typed_slice(self.out_offsets.0, self.out_offsets.1)
    }
    fn in_offsets(&self) -> &[usize] {
        self.region.typed_slice(self.in_offsets.0, self.in_offsets.1)
    }
    fn adjacency(&self) -> Adjacency<'_> {
        Adjacency::Raw {
            out_targets: self.region.typed_slice(self.out_targets.0, self.out_targets.1),
            in_sources: self.region.typed_slice(self.in_sources.0, self.in_sources.1),
            in_edge_ids: self.region.typed_slice(self.in_edge_ids.0, self.in_edge_ids.1),
        }
    }
    fn heap_bytes(&self) -> usize {
        0
    }
    fn mapped_bytes(&self) -> usize {
        (self.out_offsets.1 + self.in_offsets.1 + self.in_edge_ids.1) * 8
            + (self.out_targets.1 + self.in_sources.1) * 4
    }
    fn mode(&self) -> StoreMode {
        StoreMode::Mmap
    }
}

/// Varint-delta compressed adjacency; offsets stay raw so degree math
/// and `out_degree_prefix` keep their O(1) contracts.
#[derive(Debug, Clone)]
pub struct CompressedBacking {
    pub(crate) out_offsets: Vec<usize>,
    pub(crate) in_offsets: Vec<usize>,
    pub(crate) out_targets: CompressedSeq,
    pub(crate) in_sources: CompressedSeq,
    pub(crate) in_edge_ids: CompressedSeq,
}

impl CompressedBacking {
    /// Encode raw CSR/CSC arrays (order-preserving; see module doc).
    pub(crate) fn encode(
        out_offsets: Vec<usize>,
        out_targets: &[VertexId],
        in_offsets: Vec<usize>,
        in_sources: &[VertexId],
        in_edge_ids: &[usize],
    ) -> CompressedBacking {
        CompressedBacking {
            out_targets: CompressedSeq::encode(out_targets.iter().map(|&t| t as u64)),
            in_sources: CompressedSeq::encode(in_sources.iter().map(|&s| s as u64)),
            in_edge_ids: CompressedSeq::encode(in_edge_ids.iter().map(|&e| e as u64)),
            out_offsets,
            in_offsets,
        }
    }
}

impl TopologySource for CompressedBacking {
    fn out_offsets(&self) -> &[usize] {
        &self.out_offsets
    }
    fn in_offsets(&self) -> &[usize] {
        &self.in_offsets
    }
    fn adjacency(&self) -> Adjacency<'_> {
        Adjacency::Packed {
            out_targets: &self.out_targets,
            in_sources: &self.in_sources,
            in_edge_ids: &self.in_edge_ids,
        }
    }
    fn heap_bytes(&self) -> usize {
        (self.out_offsets.len() + self.in_offsets.len()) * 8
            + self.out_targets.heap_bytes()
            + self.in_sources.heap_bytes()
            + self.in_edge_ids.heap_bytes()
    }
    fn mapped_bytes(&self) -> usize {
        0
    }
    fn mode(&self) -> StoreMode {
        StoreMode::Compressed
    }
}

/// The closed set of backings a [`Topology`](crate::graph::csr::Topology)
/// dispatches over (static dispatch; the enum is the `dyn`-free form of
/// the [`TopologySource`] abstraction).
#[derive(Debug, Clone)]
pub enum Backing {
    /// Heap `Vec`s.
    Heap(HeapBacking),
    /// Mapped binfmt v2 snapshot.
    Mmap(MmapBacking),
    /// Varint-delta compressed.
    Compressed(CompressedBacking),
}

impl Backing {
    /// The backing as its trait surface.
    #[inline]
    pub fn source(&self) -> &dyn TopologySource {
        match self {
            Backing::Heap(b) => b,
            Backing::Mmap(b) => b,
            Backing::Compressed(b) => b,
        }
    }

    /// CSR row offsets (always raw, whatever the backing).
    #[inline]
    pub fn out_offsets(&self) -> &[usize] {
        match self {
            Backing::Heap(b) => &b.out_offsets,
            Backing::Mmap(b) => b.out_offsets(),
            Backing::Compressed(b) => &b.out_offsets,
        }
    }

    /// CSC row offsets (always raw, whatever the backing).
    #[inline]
    pub fn in_offsets(&self) -> &[usize] {
        match self {
            Backing::Heap(b) => &b.in_offsets,
            Backing::Mmap(b) => b.in_offsets(),
            Backing::Compressed(b) => &b.in_offsets,
        }
    }

    /// The adjacency payload.
    #[inline]
    pub fn adjacency(&self) -> Adjacency<'_> {
        self.source().adjacency()
    }
}

/// Re-encode a heap/mmap topology's adjacency into the compressed
/// backing (the `store = compressed` path for inputs that are not
/// already packed compressed). Offsets are copied raw.
pub fn compress_topology(topo: &Topology) -> Result<Topology> {
    let timer = crate::util::timer::Timer::start();
    let nv = topo.num_vertices();
    let out_offsets = topo.out_degree_prefix().to_vec();
    let in_offsets = topo.in_degree_prefix().to_vec();
    let backing = match topo.backing().adjacency() {
        Adjacency::Raw { out_targets, in_sources, in_edge_ids } => CompressedBacking::encode(
            out_offsets,
            out_targets,
            in_offsets,
            in_sources,
            in_edge_ids,
        ),
        Adjacency::Packed { .. } => {
            return Err(UniGpsError::Config("topology is already compressed".into()))
        }
    };
    let us = timer.elapsed().as_micros() as u64;
    if us > 0 {
        crate::obs::metrics::registry().store_decode_us.observe_us(us);
    }
    Ok(Topology::from_backing(nv, topo.directed(), Backing::Compressed(backing)))
}
