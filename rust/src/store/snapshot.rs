//! Binfmt v2: page-aligned, sectioned graph snapshots.
//!
//! The v1 layout (`graph::io::binfmt`) is a dense CSR stream: fine for
//! heap loads, useless for mmap (no CSC mirror — load must materialize it
//! on the heap, defeating out-of-core). V2 fixes that with a section
//! table and 4096-byte alignment so every array can be viewed in place:
//!
//! ```text
//! magic  u64  = 0x55_4E_49_47_50_53_42_32   ("UNIGPSB2")
//! nv     u64
//! ne     u64
//! flags  u64  (bit0 = directed, bit1 = compressed adjacency)
//! nsect  u64
//! nsect × { id u64, off u64, len u64 }      (section table)
//! ...sections, each at a 4096-aligned offset, zero-padded between
//! ```
//!
//! Raw layout (`flags & 2 == 0`, required for `store = mmap`):
//!
//! | id | section      | bytes        |
//! |----|--------------|--------------|
//! | 1  | out_offsets  | (nv+1) × u64 |
//! | 2  | out_targets  | ne × u32     |
//! | 3  | weights      | ne × f64     |
//! | 4  | in_offsets   | (nv+1) × u64 |
//! | 5  | in_sources   | ne × u32     |
//! | 6  | in_edge_ids  | ne × u64     |
//!
//! Compressed layout (`flags & 2 != 0`) replaces sections 2/5/6 with
//! 7/8/9: [`CompressedSeq::to_bytes`] blobs of the same arrays (offsets
//! and weights stay raw — offset prefixes must stay O(1) and weights are
//! f64 noise that varints don't help).
//!
//! Loading is fail-closed: the section table is checked against the real
//! file length **before any allocation** (a forged header cannot
//! allocation-bomb the process), then a full scan rejects non-monotone
//! offsets, out-of-range targets/sources, and a CSC mirror that is not a
//! permutation of the CSR edge ids. On the mmap path that scan doubles as
//! the sequential page-in prefault and is timed (`unigps_store_pagein_us`).

use crate::error::{Result, UniGpsError};
use crate::graph::csr::Topology;
use crate::graph::{EdgeCol, Graph, PropertyGraph};
use crate::store::{
    compress_topology, Adjacency, Backing, CompressedBacking, CompressedSeq, HeapBacking,
    MapRegion, MappedSlice, StoreMode, TopologySource,
};
use crate::util::timer::Timer;
use crate::vcprog::VertexId;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

/// V2 magic ("UNIGPSB2"; v1 is ...B1).
pub const MAGIC_V2: u64 = 0x554E_4947_5053_4232;

/// Section alignment: one page, so any mapped section is aligned for
/// every element type it can hold.
const ALIGN: u64 = 4096;

const FLAG_DIRECTED: u64 = 1;
const FLAG_COMPRESSED: u64 = 2;

const SEC_OUT_OFFSETS: u64 = 1;
const SEC_OUT_TARGETS: u64 = 2;
const SEC_WEIGHTS: u64 = 3;
const SEC_IN_OFFSETS: u64 = 4;
const SEC_IN_SOURCES: u64 = 5;
const SEC_IN_EDGE_IDS: u64 = 6;
const SEC_C_OUT_TARGETS: u64 = 7;
const SEC_C_IN_SOURCES: u64 = 8;
const SEC_C_IN_EDGE_IDS: u64 = 9;

/// Decoder cap on the section count — both layouts use 6; anything
/// larger is a corrupt or hostile table.
const MAX_SECTIONS: u64 = 16;

fn parse_err(path: &Path, what: impl std::fmt::Display) -> UniGpsError {
    UniGpsError::Parse(format!("{}: {what}", path.display()))
}

fn align_up(x: u64) -> u64 {
    x.div_ceil(ALIGN) * ALIGN
}

fn push_u64s(out: &mut Vec<u8>, words: impl Iterator<Item = u64>) {
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Little-endian u64 at byte offset `i` (bounds already established).
fn u64_at(b: &[u8], i: usize) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[i..i + 8]);
    u64::from_le_bytes(a)
}

/// Write `graph` as a binfmt v2 snapshot. `compress` selects the
/// varint-delta adjacency layout (not mappable; for `store = compressed`
/// cold starts that skip the encode pass).
pub fn pack(graph: &Graph, path: &Path, compress: bool) -> Result<()> {
    let topo = graph.topology();
    let nv = topo.num_vertices();
    let ne = topo.num_edges();
    let mut flags = if topo.directed() { FLAG_DIRECTED } else { 0 };

    let mut out_offsets = Vec::with_capacity((nv + 1) * 8);
    push_u64s(&mut out_offsets, topo.out_degree_prefix().iter().map(|&o| o as u64));
    let mut in_offsets = Vec::with_capacity((nv + 1) * 8);
    push_u64s(&mut in_offsets, topo.in_degree_prefix().iter().map(|&o| o as u64));
    let mut weights = Vec::with_capacity(ne * 8);
    for &w in graph.edge_props() {
        weights.extend_from_slice(&w.to_le_bytes());
    }

    let mut sections: Vec<(u64, Vec<u8>)> = vec![
        (SEC_OUT_OFFSETS, out_offsets),
        (SEC_WEIGHTS, weights),
        (SEC_IN_OFFSETS, in_offsets),
    ];

    if compress {
        flags |= FLAG_COMPRESSED;
        let timer = Timer::start();
        let (t, s, e) = match topo.backing().adjacency() {
            Adjacency::Raw { out_targets, in_sources, in_edge_ids } => (
                CompressedSeq::encode(out_targets.iter().map(|&x| x as u64)),
                CompressedSeq::encode(in_sources.iter().map(|&x| x as u64)),
                CompressedSeq::encode(in_edge_ids.iter().map(|&x| x as u64)),
            ),
            Adjacency::Packed { out_targets, in_sources, in_edge_ids } => {
                (out_targets.clone(), in_sources.clone(), in_edge_ids.clone())
            }
        };
        crate::obs::metrics::registry().store_decode_us.observe(timer.elapsed());
        sections.push((SEC_C_OUT_TARGETS, t.to_bytes()));
        sections.push((SEC_C_IN_SOURCES, s.to_bytes()));
        sections.push((SEC_C_IN_EDGE_IDS, e.to_bytes()));
    } else {
        let (mut targets, mut sources, mut eids) =
            (Vec::with_capacity(ne * 4), Vec::with_capacity(ne * 4), Vec::with_capacity(ne * 8));
        match topo.backing().adjacency() {
            Adjacency::Raw { out_targets, in_sources, in_edge_ids } => {
                for &t in out_targets {
                    targets.extend_from_slice(&t.to_le_bytes());
                }
                for &s in in_sources {
                    sources.extend_from_slice(&s.to_le_bytes());
                }
                push_u64s(&mut eids, in_edge_ids.iter().map(|&e| e as u64));
            }
            Adjacency::Packed { out_targets, in_sources, in_edge_ids } => {
                for t in out_targets.decode_all() {
                    targets.extend_from_slice(&(t as u32).to_le_bytes());
                }
                for s in in_sources.decode_all() {
                    sources.extend_from_slice(&(s as u32).to_le_bytes());
                }
                push_u64s(&mut eids, in_edge_ids.decode_all().into_iter());
            }
        }
        sections.push((SEC_OUT_TARGETS, targets));
        sections.push((SEC_IN_SOURCES, sources));
        sections.push((SEC_IN_EDGE_IDS, eids));
    }
    sections.sort_by_key(|(id, _)| *id);

    // Lay out: header + table, then each section at the next page boundary.
    let mut cursor = align_up(40 + sections.len() as u64 * 24);
    let mut table = Vec::with_capacity(sections.len());
    for (id, bytes) in &sections {
        table.push((*id, cursor, bytes.len() as u64));
        cursor = align_up(cursor + bytes.len() as u64);
    }

    let file = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(&MAGIC_V2.to_le_bytes())?;
    w.write_all(&(nv as u64).to_le_bytes())?;
    w.write_all(&(ne as u64).to_le_bytes())?;
    w.write_all(&flags.to_le_bytes())?;
    w.write_all(&(sections.len() as u64).to_le_bytes())?;
    for &(id, off, len) in &table {
        w.write_all(&id.to_le_bytes())?;
        w.write_all(&off.to_le_bytes())?;
        w.write_all(&len.to_le_bytes())?;
    }
    let mut written = 40 + sections.len() as u64 * 24;
    for ((_, bytes), &(_, off, _)) in sections.iter().zip(&table) {
        w.write_all(&vec![0u8; (off - written) as usize])?;
        w.write_all(bytes)?;
        written = off + bytes.len() as u64;
    }
    w.flush()?;
    Ok(())
}

/// Load a snapshot (v1 or v2, detected by magic) into the requested
/// backing. The v1 stream can only feed heap and compressed backings;
/// `store = mmap` requires a packed v2 raw file.
pub fn load(path: &Path, mode: StoreMode) -> Result<Graph> {
    let magic = {
        use std::io::Read;
        let mut b = [0u8; 8];
        std::fs::File::open(path)?
            .read_exact(&mut b)
            .map_err(|_| parse_err(path, "shorter than a snapshot magic"))?;
        u64::from_le_bytes(b)
    };
    match magic {
        crate::graph::io::binfmt::MAGIC => {
            use crate::graph::io::GraphSource;
            match mode {
                StoreMode::Heap => crate::graph::io::binfmt::BinaryFormat.load(path),
                StoreMode::Compressed => {
                    let g = crate::graph::io::binfmt::BinaryFormat.load(path)?;
                    compress_graph(&g)
                }
                StoreMode::Mmap => Err(UniGpsError::Config(format!(
                    "{} is a binfmt v1 snapshot; `store = mmap` needs the page-aligned \
                     v2 layout — repack it with `unigps pack`",
                    path.display()
                ))),
            }
        }
        MAGIC_V2 => load_v2(path, mode),
        _ => Err(parse_err(path, "bad magic (not a UniGPS snapshot)")),
    }
}

/// Re-back a heap/mmap graph onto the compressed backing.
pub fn compress_graph(g: &Graph) -> Result<Graph> {
    let topo = compress_topology(g.topology())?;
    Ok(PropertyGraph::new(Arc::new(topo), vec![(); g.num_vertices()], g.edge_props().to_vec()))
}

/// The parsed, length-checked v2 header + section table.
struct Layout {
    nv: usize,
    ne: usize,
    directed: bool,
    compressed: bool,
    /// `(id, byte offset, byte length)`, each fully inside the file.
    sections: Vec<(u64, usize, usize)>,
}

impl Layout {
    /// Parse from the file's first bytes; every count is validated
    /// against `file_len` before the caller allocates anything.
    fn parse(head: &[u8], file_len: u64, path: &Path) -> Result<Layout> {
        if head.len() < 40 {
            return Err(parse_err(path, "truncated v2 header"));
        }
        debug_assert_eq!(u64_at(head, 0), MAGIC_V2);
        let nv = u64_at(head, 8);
        let ne = u64_at(head, 16);
        let flags = u64_at(head, 24);
        let nsect = u64_at(head, 32);
        // Targets/sources are u32; counts must also be plausible against
        // the real file length (the allocation cap: a raw snapshot stores
        // >= 4 bytes per edge and 8 per offset word).
        if nv > u32::MAX as u64 {
            return Err(parse_err(path, format!("vertex count {nv} exceeds u32 ids")));
        }
        if (nv + 1) * 8 > file_len || ne / 2 > file_len {
            return Err(parse_err(
                path,
                format!("header claims {nv} vertices / {ne} edges in a {file_len}-byte file"),
            ));
        }
        if nsect > MAX_SECTIONS {
            return Err(parse_err(path, format!("implausible section count {nsect}")));
        }
        let table_end = 40 + nsect * 24;
        if head.len() < table_end as usize {
            return Err(parse_err(path, "truncated section table"));
        }
        let mut sections = Vec::with_capacity(nsect as usize);
        for i in 0..nsect as usize {
            let id = u64_at(head, 40 + i * 24);
            let off = u64_at(head, 48 + i * 24);
            let len = u64_at(head, 56 + i * 24);
            if off % ALIGN != 0 {
                return Err(parse_err(path, format!("section {id} offset {off} not page-aligned")));
            }
            let in_file = off >= table_end
                && matches!(off.checked_add(len), Some(end) if end <= file_len);
            if !in_file {
                return Err(parse_err(
                    path,
                    format!("section {id} [{off}, +{len}) outside the {file_len}-byte file"),
                ));
            }
            if sections.iter().any(|&(other, _, _)| other == id) {
                return Err(parse_err(path, format!("duplicate section {id}")));
            }
            sections.push((id, off as usize, len as usize));
        }
        Ok(Layout {
            nv: nv as usize,
            ne: ne as usize,
            directed: flags & FLAG_DIRECTED != 0,
            compressed: flags & FLAG_COMPRESSED != 0,
            sections,
        })
    }

    /// A required section's `(offset, len)`, length-checked against the
    /// exact expected byte count (`None` expected = variable length).
    fn section(&self, id: u64, expect: Option<usize>, path: &Path) -> Result<(usize, usize)> {
        let &(_, off, len) = self
            .sections
            .iter()
            .find(|&&(i, _, _)| i == id)
            .ok_or_else(|| parse_err(path, format!("missing section {id}")))?;
        if let Some(want) = expect {
            if len != want {
                return Err(parse_err(
                    path,
                    format!("section {id} is {len} bytes, expected {want}"),
                ));
            }
        }
        Ok((off, len))
    }
}

/// Full-scan validation of raw CSR/CSC arrays: monotone offsets, in-range
/// targets/sources, and the CSC mirror a permutation of the CSR edge ids
/// with `out_targets[eid] == v` for every CSC slot under `v`. On mmap
/// this sequential pass is also the page-in prefault.
fn validate_raw(
    nv: usize,
    ne: usize,
    out_offsets: &[usize],
    out_targets: &[VertexId],
    in_offsets: &[usize],
    in_sources: &[VertexId],
    in_edge_ids: &[usize],
    path: &Path,
) -> Result<()> {
    for (name, offsets) in [("out_offsets", out_offsets), ("in_offsets", in_offsets)] {
        if offsets[0] != 0 || offsets[nv] != ne {
            return Err(parse_err(path, format!("{name} must span [0, {ne}]")));
        }
        if let Some(v) = (0..nv).find(|&v| offsets[v] > offsets[v + 1]) {
            return Err(parse_err(path, format!("{name} non-monotone at vertex {v}")));
        }
    }
    if let Some(&t) = out_targets.iter().find(|&&t| t as usize >= nv) {
        return Err(parse_err(path, format!("edge target {t} out of range")));
    }
    if let Some(&s) = in_sources.iter().find(|&&s| s as usize >= nv) {
        return Err(parse_err(path, format!("edge source {s} out of range")));
    }
    let mut seen = vec![0u64; ne.div_ceil(64)];
    for v in 0..nv {
        for slot in in_offsets[v]..in_offsets[v + 1] {
            let eid = in_edge_ids[slot];
            if eid >= ne {
                return Err(parse_err(path, format!("CSC edge id {eid} out of range")));
            }
            if out_targets[eid] as usize != v {
                return Err(parse_err(
                    path,
                    format!("CSC slot {slot} claims edge {eid}, whose target is not {v}"),
                ));
            }
            if seen[eid / 64] >> (eid % 64) & 1 != 0 {
                return Err(parse_err(path, format!("CSC maps edge {eid} twice")));
            }
            seen[eid / 64] |= 1 << (eid % 64);
        }
    }
    Ok(())
}

fn load_v2(path: &Path, mode: StoreMode) -> Result<Graph> {
    match mode {
        StoreMode::Mmap => load_v2_mmap(path),
        StoreMode::Heap | StoreMode::Compressed => load_v2_resident(path, mode),
    }
}

fn load_v2_mmap(path: &Path) -> Result<Graph> {
    let reg = crate::obs::metrics::registry();
    let timer = Timer::start();
    let region = Arc::new(MapRegion::open(path)?);
    let layout = Layout::parse(region.bytes(), region.len() as u64, path)?;
    if layout.compressed {
        return Err(UniGpsError::Config(format!(
            "{} is a compressed snapshot; `store = mmap` needs the raw v2 layout \
             (repack without --compress)",
            path.display()
        )));
    }
    let (nv, ne) = (layout.nv, layout.ne);
    let out_offsets = layout.section(SEC_OUT_OFFSETS, Some((nv + 1) * 8), path)?;
    let out_targets = layout.section(SEC_OUT_TARGETS, Some(ne * 4), path)?;
    let weights = layout.section(SEC_WEIGHTS, Some(ne * 8), path)?;
    let in_offsets = layout.section(SEC_IN_OFFSETS, Some((nv + 1) * 8), path)?;
    let in_sources = layout.section(SEC_IN_SOURCES, Some(ne * 4), path)?;
    let in_edge_ids = layout.section(SEC_IN_EDGE_IDS, Some(ne * 8), path)?;
    let backing = crate::store::MmapBacking {
        region: region.clone(),
        out_offsets: (out_offsets.0, nv + 1),
        out_targets: (out_targets.0, ne),
        in_offsets: (in_offsets.0, nv + 1),
        in_sources: (in_sources.0, ne),
        in_edge_ids: (in_edge_ids.0, ne),
    };
    reg.store_map_us.observe(timer.elapsed());

    let timer = Timer::start();
    match backing.adjacency() {
        Adjacency::Raw { out_targets, in_sources, in_edge_ids } => validate_raw(
            nv,
            ne,
            backing.out_offsets(),
            out_targets,
            backing.in_offsets(),
            in_sources,
            in_edge_ids,
            path,
        )?,
        Adjacency::Packed { .. } => unreachable!("mmap backing is raw"),
    }
    reg.store_pagein_us.observe(timer.elapsed());

    let topo = Topology::from_backing(nv, layout.directed, Backing::Mmap(backing));
    let col = EdgeCol::Mapped(MappedSlice::<f64>::new(region, weights.0, ne));
    Ok(PropertyGraph::from_cols(Arc::new(topo), vec![(); nv], col))
}

fn load_v2_resident(path: &Path, mode: StoreMode) -> Result<Graph> {
    let bytes = std::fs::read(path)?;
    let layout = Layout::parse(&bytes, bytes.len() as u64, path)?;
    let (nv, ne) = (layout.nv, layout.ne);

    let decode_u64s = |(off, len): (usize, usize)| -> Vec<usize> {
        (0..len / 8).map(|i| u64_at(&bytes, off + i * 8) as usize).collect()
    };
    let out_offsets = decode_u64s(layout.section(SEC_OUT_OFFSETS, Some((nv + 1) * 8), path)?);
    let in_offsets = decode_u64s(layout.section(SEC_IN_OFFSETS, Some((nv + 1) * 8), path)?);
    let (woff, _) = layout.section(SEC_WEIGHTS, Some(ne * 8), path)?;
    let weights: Vec<f64> =
        (0..ne).map(|i| f64::from_bits(u64_at(&bytes, woff + i * 8))).collect();

    let backing = if layout.compressed {
        let timer = Timer::start();
        let seq = |id, what, limit| -> Result<CompressedSeq> {
            let (off, len) = layout.section(id, None, path)?;
            let seq = CompressedSeq::from_bytes(&bytes[off..off + len], what, limit)?;
            if seq.len() != ne {
                let got = seq.len();
                return Err(parse_err(path, format!("{what} has {got} values, expected {ne}")));
            }
            Ok(seq)
        };
        // `max(1)` keeps empty sequences vacuously valid when nv/ne is 0.
        let out_targets = seq(SEC_C_OUT_TARGETS, "out_targets", (nv as u64).max(1))?;
        let in_sources = seq(SEC_C_IN_SOURCES, "in_sources", (nv as u64).max(1))?;
        let in_edge_ids = seq(SEC_C_IN_EDGE_IDS, "in_edge_ids", (ne as u64).max(1))?;
        // Offsets still need the monotone/span checks the raw scan does.
        for (name, offsets) in [("out_offsets", &out_offsets), ("in_offsets", &in_offsets)] {
            if offsets[0] != 0
                || offsets[nv] != ne
                || (0..nv).any(|v| offsets[v] > offsets[v + 1])
            {
                return Err(parse_err(path, format!("{name} must be monotone over [0, {ne}]")));
            }
        }
        let packed = CompressedBacking {
            out_offsets,
            in_offsets,
            out_targets,
            in_sources,
            in_edge_ids,
        };
        crate::obs::metrics::registry().store_decode_us.observe(timer.elapsed());
        match mode {
            StoreMode::Compressed => Backing::Compressed(packed),
            StoreMode::Heap => Backing::Heap(HeapBacking {
                out_offsets: packed.out_offsets.clone(),
                out_targets: packed.out_targets.decode_all().iter().map(|&t| t as u32).collect(),
                in_offsets: packed.in_offsets.clone(),
                in_sources: packed.in_sources.decode_all().iter().map(|&s| s as u32).collect(),
                in_edge_ids: packed.in_edge_ids.decode_all().iter().map(|&e| e as usize).collect(),
            }),
            StoreMode::Mmap => unreachable!("handled by load_v2_mmap"),
        }
    } else {
        let (toff, _) = layout.section(SEC_OUT_TARGETS, Some(ne * 4), path)?;
        let (soff, _) = layout.section(SEC_IN_SOURCES, Some(ne * 4), path)?;
        let out_targets: Vec<VertexId> = (0..ne)
            .map(|i| {
                let mut a = [0u8; 4];
                a.copy_from_slice(&bytes[toff + i * 4..toff + i * 4 + 4]);
                u32::from_le_bytes(a)
            })
            .collect();
        let in_sources: Vec<VertexId> = (0..ne)
            .map(|i| {
                let mut a = [0u8; 4];
                a.copy_from_slice(&bytes[soff + i * 4..soff + i * 4 + 4]);
                u32::from_le_bytes(a)
            })
            .collect();
        let in_edge_ids = decode_u64s(layout.section(SEC_IN_EDGE_IDS, Some(ne * 8), path)?);
        validate_raw(
            nv,
            ne,
            &out_offsets,
            &out_targets,
            &in_offsets,
            &in_sources,
            &in_edge_ids,
            path,
        )?;
        let heap = HeapBacking { out_offsets, out_targets, in_offsets, in_sources, in_edge_ids };
        match mode {
            StoreMode::Heap => Backing::Heap(heap),
            StoreMode::Compressed => {
                let timer = Timer::start();
                let packed = CompressedBacking::encode(
                    heap.out_offsets,
                    &heap.out_targets,
                    heap.in_offsets,
                    &heap.in_sources,
                    &heap.in_edge_ids,
                );
                crate::obs::metrics::registry().store_decode_us.observe(timer.elapsed());
                Backing::Compressed(packed)
            }
            StoreMode::Mmap => unreachable!("handled by load_v2_mmap"),
        }
    };

    let topo = Topology::from_backing(nv, layout.directed, backing);
    Ok(PropertyGraph::new(Arc::new(topo), vec![(); nv], weights))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::random_for_tests;
    use crate::graph::io::tmp_path;

    fn assert_same(a: &Graph, b: &Graph) {
        assert_eq!(a.num_vertices(), b.num_vertices());
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.topology().directed(), b.topology().directed());
        for v in 0..a.num_vertices() as VertexId {
            assert_eq!(
                a.topology().out_edges(v).collect::<Vec<_>>(),
                b.topology().out_edges(v).collect::<Vec<_>>()
            );
            assert_eq!(
                a.topology().in_edges(v).collect::<Vec<_>>(),
                b.topology().in_edges(v).collect::<Vec<_>>()
            );
        }
        let (wa, wb) = (a.edge_props(), b.edge_props());
        assert_eq!(wa.len(), wb.len());
        for (x, y) in wa.iter().zip(wb) {
            assert_eq!(x.to_bits(), y.to_bits(), "weights must be bit-identical");
        }
    }

    #[test]
    fn v2_roundtrips_through_all_backings() {
        let g = random_for_tests(200, 900, 11);
        for compress in [false, true] {
            let p = tmp_path(&format!("v2-rt-{compress}.bin"));
            pack(&g, &p, compress).unwrap();
            for mode in [StoreMode::Heap, StoreMode::Compressed] {
                let back = load(&p, mode).unwrap();
                assert_eq!(back.topology().store_mode(), mode);
                assert_same(&g, &back);
            }
            if compress {
                // Compressed files cannot be mapped.
                assert!(matches!(load(&p, StoreMode::Mmap), Err(UniGpsError::Config(_))));
            } else {
                let back = load(&p, StoreMode::Mmap).unwrap();
                assert_eq!(back.topology().store_mode(), StoreMode::Mmap);
                assert_eq!(back.topology().heap_bytes(), 0, "mmap load must not heap the arrays");
                assert!(back.mapped_bytes() > 0);
                assert_same(&g, &back);
            }
            let _ = std::fs::remove_file(&p);
        }
    }

    #[test]
    fn v2_handles_empty_and_single_vertex_graphs() {
        for (nv, ne) in [(0usize, 0usize), (1, 0)] {
            let g: Graph = PropertyGraph::new(
                Arc::new(Topology::from_csr(nv, vec![0; nv + 1], vec![], true)),
                vec![(); nv],
                vec![],
            );
            for compress in [false, true] {
                let p = tmp_path(&format!("v2-tiny-{nv}-{compress}.bin"));
                pack(&g, &p, compress).unwrap();
                let modes: &[StoreMode] = if compress {
                    &[StoreMode::Heap, StoreMode::Compressed]
                } else {
                    &[StoreMode::Heap, StoreMode::Compressed, StoreMode::Mmap]
                };
                for &mode in modes {
                    assert_same(&g, &load(&p, mode).unwrap());
                }
                let _ = std::fs::remove_file(&p);
            }
        }
    }

    #[test]
    fn v1_files_load_everywhere_except_mmap() {
        use crate::graph::io::{GraphSink, GraphSource};
        let g = random_for_tests(64, 256, 3);
        let p = tmp_path("v1-modes.bin");
        crate::graph::io::binfmt::BinaryFormat.store(&g, &p).unwrap();
        assert_same(&g, &load(&p, StoreMode::Heap).unwrap());
        let c = load(&p, StoreMode::Compressed).unwrap();
        assert_eq!(c.topology().store_mode(), StoreMode::Compressed);
        assert_same(&g, &c);
        assert!(matches!(load(&p, StoreMode::Mmap), Err(UniGpsError::Config(_))));
        // And a v2 file loads through the generic binfmt source (magic
        // dispatch), so `.bin` readers never care which version they get.
        let p2 = tmp_path("v2-via-binfmt.bin");
        pack(&g, &p2, false).unwrap();
        assert_same(&g, &crate::graph::io::binfmt::BinaryFormat.load(&p2).unwrap());
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(&p2);
    }

    /// Malformed-file corpus: every mutation must produce a typed error,
    /// never a panic or an allocation bomb.
    #[test]
    fn v2_malformed_corpus_is_rejected() {
        let g = random_for_tests(50, 200, 9);
        let p = tmp_path("v2-corpus.bin");
        pack(&g, &p, false).unwrap();
        let good = std::fs::read(&p).unwrap();

        let mutate = |name: &str, f: &dyn Fn(&mut Vec<u8>)| {
            let mut bad = good.clone();
            f(&mut bad);
            let bp = tmp_path(&format!("v2-corpus-{name}.bin"));
            std::fs::write(&bp, &bad).unwrap();
            for mode in [StoreMode::Heap, StoreMode::Compressed, StoreMode::Mmap] {
                let err = load(&bp, mode).expect_err(name);
                assert!(
                    matches!(err, UniGpsError::Parse(_)),
                    "{name}/{mode:?}: expected Parse, got {err:?}"
                );
            }
            let _ = std::fs::remove_file(&bp);
        };

        // Forged vertex count far past the file length (allocation bomb).
        mutate("forged-nv", &|b| b[8..16].copy_from_slice(&(u32::MAX as u64).to_le_bytes()));
        // Forged edge count.
        mutate("forged-ne", &|b| b[16..24].copy_from_slice(&u64::MAX.to_le_bytes()));
        // Implausible section count.
        mutate("forged-nsect", &|b| b[32..40].copy_from_slice(&1000u64.to_le_bytes()));
        // Section pushed past EOF.
        mutate("section-past-eof", &|b| {
            let off = u64_at(b, 48);
            b[48..56].copy_from_slice(&(off + (1 << 40)).to_le_bytes());
        });
        // Misaligned section offset.
        mutate("misaligned-section", &|b| {
            let off = u64_at(b, 48);
            b[48..56].copy_from_slice(&(off + 4).to_le_bytes());
        });
        // Non-monotone out_offsets: setting offsets[1] past ne guarantees
        // a descent before the (unchanged) final prefix word. The section
        // table is sorted by id, so entry 0 is out_offsets.
        mutate("non-monotone-offsets", &|b| {
            let off = u64_at(b, 48) as usize;
            b[off + 8..off + 16].copy_from_slice(&(200u64 + 1).to_le_bytes());
        });
        // Out-of-range edge target (entry 1 is out_targets).
        mutate("bad-target", &|b| {
            let off = u64_at(b, 48 + 24) as usize;
            b[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        });
        // CSC mirror pointing at the wrong CSR edge (entry 3 is
        // in_edge_ids — ids sort as 1,2,3,4,5,6 → index 3 is id 4? No:
        // index 3 is in_offsets (id 4); in_edge_ids is id 6, index 5).
        mutate("bad-csc-mirror", &|b| {
            let off = u64_at(b, 48 + 5 * 24) as usize;
            let first = u64_at(b, off);
            b[off..off + 8].copy_from_slice(&(first ^ 1).to_le_bytes());
        });
        // Truncated behind the table.
        mutate("truncated", &|b| b.truncate(b.len() / 2));

        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn compressed_file_with_forged_stream_is_rejected() {
        let g = random_for_tests(80, 300, 21);
        let p = tmp_path("v2-cbad.bin");
        pack(&g, &p, true).unwrap();
        let mut bad = std::fs::read(&p).unwrap();
        // Entry order by id: 1,3,4,7,8,9 → index 3 is the compressed
        // out_targets blob; forge its value count.
        let off = u64_at(&bad, 48 + 3 * 24) as usize;
        bad[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bad).unwrap();
        for mode in [StoreMode::Heap, StoreMode::Compressed] {
            assert!(matches!(load(&p, mode), Err(UniGpsError::Parse(_))));
        }
        let _ = std::fs::remove_file(&p);
    }
}
