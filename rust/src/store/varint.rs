//! Varint-delta sequence compression for adjacency arrays.
//!
//! A [`CompressedSeq`] stores a flat `u64` sequence (edge targets, CSC
//! sources, CSC→CSR edge-id maps) as LEB128 varints: the first value of
//! every 64-entry block is written **absolute**, every other value as the
//! **zigzag-encoded delta** from its predecessor. A skip table records the
//! byte offset of each block start, so a cursor seeks to any index by
//! jumping to the covering block and decoding at most 63 values forward.
//!
//! Encoding deltas (rather than sorting rows first) preserves the exact
//! stored edge order, so every engine folds messages in the same order as
//! the heap backing and results stay **bit-identical** — sorted rows just
//! compress best. Because block starts are absolute, blocks decode
//! independently and a corrupt suffix cannot poison earlier blocks.

use crate::error::{Result, UniGpsError};

/// Entries per skip block. 64 keeps the skip table at ~1.6% of a
/// 4-byte-per-entry raw array while bounding a seek to 63 decode steps.
pub const BLOCK: usize = 64;

#[inline]
fn zigzag(d: i64) -> u64 {
    ((d << 1) ^ (d >> 63)) as u64
}

#[inline]
fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

#[inline]
fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Decode one LEB128 varint at `pos`, advancing it. Returns 0 past the
/// end — every loaded sequence is fully validated once at load time
/// ([`CompressedSeq::validate`]), so a live cursor never reaches here
/// out of bounds.
#[inline]
fn read_varint(data: &[u8], pos: &mut usize) -> u64 {
    let mut v = 0u64;
    let mut shift = 0u32;
    while *pos < data.len() {
        let b = data[*pos];
        *pos += 1;
        v |= u64::from(b & 0x7f) << shift.min(63);
        if b & 0x80 == 0 {
            return v;
        }
        shift += 7;
        if shift >= 64 {
            break;
        }
    }
    v
}

/// An immutable varint-delta compressed `u64` sequence with per-block
/// skip offsets (see the module doc for the layout rationale).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompressedSeq {
    len: usize,
    /// Byte offset of each block start inside `data`.
    skip: Vec<u64>,
    data: Vec<u8>,
}

impl CompressedSeq {
    /// Encode a sequence. The iterator's `len` is trusted (`ExactSizeIterator`).
    pub fn encode(values: impl ExactSizeIterator<Item = u64>) -> CompressedSeq {
        let len = values.len();
        let mut skip = Vec::with_capacity(len.div_ceil(BLOCK));
        let mut data = Vec::new();
        let mut prev = 0u64;
        for (i, v) in values.enumerate() {
            if i % BLOCK == 0 {
                skip.push(data.len() as u64);
                push_varint(&mut data, v);
            } else {
                push_varint(&mut data, zigzag((v as i64).wrapping_sub(prev as i64)));
            }
            prev = v;
        }
        CompressedSeq { len, skip, data }
    }

    /// Number of encoded values.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no values are encoded.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Heap bytes held by the encoded form.
    pub fn heap_bytes(&self) -> usize {
        self.data.len() + self.skip.len() * 8
    }

    /// A cursor positioned at value `idx` (seek to the covering block,
    /// decode forward). `idx >= len` yields an exhausted cursor.
    pub fn cursor_at(&self, idx: usize) -> SeqCursor<'_> {
        if idx >= self.len {
            return SeqCursor { data: &self.data, pos: self.data.len(), prev: 0, idx };
        }
        let block = idx / BLOCK;
        let mut cur = SeqCursor {
            data: &self.data,
            pos: self.skip[block] as usize,
            prev: 0,
            idx: block * BLOCK,
        };
        for _ in 0..(idx - block * BLOCK) {
            cur.next_value();
        }
        cur
    }

    /// Decode the whole sequence to a `Vec` (pack/unpack paths only; the
    /// engines decode row windows through [`CompressedSeq::cursor_at`]).
    pub fn decode_all(&self) -> Vec<u64> {
        let mut cur = self.cursor_at(0);
        (0..self.len).map(|_| cur.next_value()).collect()
    }

    /// Full decode pass checking structure and value bounds: every skip
    /// entry in range, every value `< limit`, and the final cursor
    /// consuming exactly the data buffer. Loaded (untrusted) sequences
    /// must pass here before any cursor is handed to an engine.
    pub fn validate(&self, what: &str, limit: u64) -> Result<()> {
        if self.skip.len() != self.len.div_ceil(BLOCK) {
            return Err(UniGpsError::Parse(format!(
                "compressed {what}: skip table has {} blocks, expected {}",
                self.skip.len(),
                self.len.div_ceil(BLOCK)
            )));
        }
        let mut cur = SeqCursor { data: &self.data, pos: 0, prev: 0, idx: 0 };
        for i in 0..self.len {
            if i % BLOCK == 0 {
                let want = self.skip[i / BLOCK] as usize;
                if cur.pos != want {
                    return Err(UniGpsError::Parse(format!(
                        "compressed {what}: block {} starts at byte {} but skip table says {want}",
                        i / BLOCK,
                        cur.pos
                    )));
                }
            }
            if cur.pos >= self.data.len() {
                return Err(UniGpsError::Parse(format!(
                    "compressed {what}: truncated at value {i} of {}",
                    self.len
                )));
            }
            let v = cur.next_value();
            if v >= limit {
                return Err(UniGpsError::Parse(format!(
                    "compressed {what}: value {v} at index {i} out of range (limit {limit})"
                )));
            }
        }
        if cur.pos != self.data.len() {
            return Err(UniGpsError::Parse(format!(
                "compressed {what}: {} trailing bytes after the last value",
                self.data.len() - cur.pos
            )));
        }
        Ok(())
    }

    /// Serialize for a binfmt v2 section:
    /// `len u64 | nskip u64 | skip u64× | data bytes`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.skip.len() * 8 + self.data.len());
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        out.extend_from_slice(&(self.skip.len() as u64).to_le_bytes());
        for &s in &self.skip {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out.extend_from_slice(&self.data);
        out
    }

    /// Parse a serialized sequence, then [`CompressedSeq::validate`] it
    /// against `limit`. All counts are bounded by the actual byte length,
    /// so a forged header cannot request an oversized allocation.
    pub fn from_bytes(buf: &[u8], what: &str, limit: u64) -> Result<CompressedSeq> {
        let take_u64 = |buf: &[u8], at: usize| -> Result<u64> {
            buf.get(at..at + 8)
                .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                .ok_or_else(|| UniGpsError::Parse(format!("compressed {what}: truncated header")))
        };
        let len = take_u64(buf, 0)? as usize;
        let nskip = take_u64(buf, 8)? as usize;
        // A varint takes >= 1 byte, so `len` can never exceed the payload
        // bytes; the skip table is bounded the same way. This is the
        // allocation cap — reject before reserving anything.
        let payload = buf.len().saturating_sub(16);
        if nskip.saturating_mul(8) > payload || len > payload.saturating_sub(nskip * 8) {
            return Err(UniGpsError::Parse(format!(
                "compressed {what}: header claims {len} values / {nskip} blocks in {payload} bytes"
            )));
        }
        let mut skip = Vec::with_capacity(nskip);
        for i in 0..nskip {
            skip.push(take_u64(buf, 16 + i * 8)?);
        }
        let data = buf[16 + nskip * 8..].to_vec();
        for &s in &skip {
            if s as usize > data.len() {
                return Err(UniGpsError::Parse(format!(
                    "compressed {what}: skip offset {s} past data end {}",
                    data.len()
                )));
            }
        }
        let seq = CompressedSeq { len, skip, data };
        seq.validate(what, limit)?;
        Ok(seq)
    }
}

/// A forward decode cursor over a [`CompressedSeq`].
#[derive(Debug, Clone)]
pub struct SeqCursor<'a> {
    data: &'a [u8],
    pos: usize,
    prev: u64,
    idx: usize,
}

impl SeqCursor<'_> {
    /// Decode the next value and advance. Callers bound iteration by the
    /// sequence length (validated at load), never by probing.
    #[inline]
    pub fn next_value(&mut self) -> u64 {
        let raw = read_varint(self.data, &mut self.pos);
        let v = if self.idx % BLOCK == 0 {
            raw
        } else {
            self.prev.wrapping_add(unzigzag(raw) as u64)
        };
        self.idx += 1;
        self.prev = v;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(values: &[u64]) {
        let seq = CompressedSeq::encode(values.iter().copied());
        assert_eq!(seq.len(), values.len());
        assert_eq!(seq.decode_all(), values);
        let limit = values.iter().copied().max().map_or(1, |m| m + 1);
        seq.validate("test", limit).unwrap();
        // Serialized form survives parse + validation.
        let back = CompressedSeq::from_bytes(&seq.to_bytes(), "test", limit).unwrap();
        assert_eq!(back, seq);
    }

    #[test]
    fn empty_sequence() {
        roundtrip(&[]);
        let seq = CompressedSeq::encode(std::iter::empty());
        assert!(seq.is_empty());
        // A cursor at 0 of an empty sequence is exhausted, never read.
        let _ = seq.cursor_at(0);
    }

    #[test]
    fn single_value() {
        roundtrip(&[0]);
        roundtrip(&[u32::MAX as u64]);
    }

    #[test]
    fn unsorted_rows_preserve_order() {
        // Deltas can be negative (unsorted adjacency rows) — order must
        // survive exactly, not canonicalized.
        roundtrip(&[5, 3, 9, 0, 7, 7, 2]);
    }

    #[test]
    fn hub_row_spanning_many_blocks() {
        // A max-degree hub: thousands of entries crossing block starts.
        let values: Vec<u64> = (0..10_000u64).map(|i| (i * 37) % 4096).collect();
        roundtrip(&values);
        let seq = CompressedSeq::encode(values.iter().copied());
        // Seek into the middle of a block and read across a boundary.
        for &start in &[0usize, 1, 63, 64, 65, 4096, 9_999] {
            let mut cur = seq.cursor_at(start);
            for (off, want) in values[start..].iter().take(130).enumerate() {
                assert_eq!(cur.next_value(), *want, "start {start} offset {off}");
            }
        }
    }

    #[test]
    fn sorted_rows_compress_well() {
        let values: Vec<u64> = (0..100_000u64).collect();
        let seq = CompressedSeq::encode(values.iter().copied());
        // Sorted runs are ~1 byte per entry vs 4 raw.
        assert!(seq.heap_bytes() < values.len() * 2, "{} bytes", seq.heap_bytes());
        assert_eq!(seq.decode_all(), values);
    }

    #[test]
    fn cursor_past_end_is_exhausted_not_panicking() {
        let seq = CompressedSeq::encode([1u64, 2, 3].into_iter());
        let _ = seq.cursor_at(3);
        let _ = seq.cursor_at(64);
    }

    #[test]
    fn validation_rejects_out_of_range_values() {
        let seq = CompressedSeq::encode([1u64, 2, 99].into_iter());
        assert!(seq.validate("t", 100).is_ok());
        let err = seq.validate("t", 99).unwrap_err();
        assert!(matches!(err, UniGpsError::Parse(_)));
    }

    #[test]
    fn from_bytes_rejects_forged_counts() {
        let seq = CompressedSeq::encode((0..100u64).map(|i| i % 7));
        let mut bytes = seq.to_bytes();
        // Forge an absurd value count: rejected against the byte length
        // before any allocation.
        bytes[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = CompressedSeq::from_bytes(&bytes, "t", 7).unwrap_err();
        assert!(matches!(err, UniGpsError::Parse(_)));
        // Truncated payload: typed parse error, not a panic.
        let seq2 = CompressedSeq::encode((0..1000u64).map(|i| i % 11));
        let bytes = seq2.to_bytes();
        let err = CompressedSeq::from_bytes(&bytes[..bytes.len() / 2], "t", 11).unwrap_err();
        assert!(matches!(err, UniGpsError::Parse(_)));
    }
}
