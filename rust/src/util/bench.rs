//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated measurement with simple robust statistics
//! (median / mean / stddev / min) and a uniform textual report format that
//! the `benches/` binaries use to regenerate the paper's tables and figures.
//! Every bench binary is registered with `harness = false`, so `cargo bench`
//! simply runs their `main`.

use std::time::{Duration, Instant};

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// All sample durations (seconds).
    pub samples: Vec<f64>,
}

impl BenchStats {
    /// Median of the samples in seconds.
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    /// Mean of the samples in seconds.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation in seconds.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Minimum sample in seconds.
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// One-line human report.
    pub fn report(&self) -> String {
        format!(
            "{:<48} median {:>10}  mean {:>10}  ±{:>8}  min {:>10}  (n={})",
            self.name,
            fmt_dur(self.median()),
            fmt_dur(self.mean()),
            fmt_dur(self.stddev()),
            fmt_dur(self.min()),
            self.samples.len()
        )
    }
}

/// Format a duration in adaptive units.
pub fn fmt_dur(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".into();
    }
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}µs", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Hard cap on total measured time; sampling stops early past this.
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 1,
            iters: 5,
            max_total: Duration::from_secs(60),
        }
    }
}

impl Bencher {
    /// Construct with explicit warmup/iteration counts.
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher {
            warmup,
            iters,
            max_total: Duration::from_secs(120),
        }
    }

    /// Honour `UNIGPS_BENCH_FAST=1` by dropping to 1 warmup / 2 iters.
    /// Used by CI and the final `cargo bench` log to keep wallclock bounded.
    pub fn from_env(self) -> Self {
        if std::env::var("UNIGPS_BENCH_FAST").ok().as_deref() == Some("1") {
            Bencher {
                warmup: 0,
                iters: 2,
                max_total: Duration::from_secs(30),
            }
        } else {
            self
        }
    }

    /// Measure closure `f`, returning robust statistics.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let total_start = Instant::now();
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
            if total_start.elapsed() > self.max_total && !samples.is_empty() {
                break;
            }
        }
        BenchStats {
            name: name.to_string(),
            samples,
        }
    }
}

/// Render a fixed-width table to stdout; used by the figure/table benches so
/// the output mirrors the paper's rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render the table as a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = BenchStats {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 100.0],
        };
        assert!((s.median() - 3.0).abs() < 1e-12);
        assert!((s.mean() - 22.0).abs() < 1e-12);
        assert!((s.min() - 1.0).abs() < 1e-12);
        assert!(s.stddev() > 0.0);
        assert!(s.report().contains("median"));
    }

    #[test]
    fn bencher_collects_samples() {
        let b = Bencher::new(1, 3);
        let s = b.bench("noop", || 1 + 1);
        assert_eq!(s.samples.len(), 3);
        assert!(s.median() >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(2.5), "2.500s");
        assert_eq!(fmt_dur(0.0025), "2.500ms");
        assert_eq!(fmt_dur(2.5e-6), "2.500µs");
        assert_eq!(fmt_dur(5e-9), "5.0ns");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["alg", "time"]);
        t.row(&["pagerank".into(), "1.0s".into()]);
        t.row(&["cc".into(), "0.5s".into()]);
        let r = t.render();
        assert!(r.contains("| alg"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
