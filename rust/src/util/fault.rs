//! Failpoint registry — deterministic fault injection for chaos testing.
//!
//! Production code marks its interesting failure sites with
//! [`fault::point!`](crate::util::fault::point) (a named *failpoint*);
//! normally every site is a no-op behind one relaxed atomic load. When a
//! fault spec is activated — via the `UNIGPS_FAULTS` environment variable
//! at first use, or programmatically with [`activate`] from a test — each
//! named point can
//!
//! * **`error`** — fail with a typed error,
//! * **`delay:MS`** — sleep `MS` milliseconds (latency injection), or
//! * **`drop`** — simulate a dropped connection (an
//!   `io::ErrorKind::ConnectionReset` at I/O sites),
//!
//! each with an optional firing probability (`@0.25`). Decisions are
//! **deterministic**: whether a point fires on its *n*-th hit is a pure
//! function of `(point name, n, seed)` via a splitmix64 mix — a chaos run
//! replays exactly from its spec, independent of thread scheduling of
//! *other* points (each point keeps its own hit counter).
//!
//! Spec grammar (full reference in `docs/robustness.md`):
//!
//! ```text
//! spec   := clause (';' clause)*
//! clause := 'seed' '=' u64            -- decision seed (default 0)
//!         | point '=' action ['@' p]  -- p in (0, 1], default 1 (always)
//! action := 'error' | 'drop' | 'delay' ':' millis
//! ```
//!
//! Example: `UNIGPS_FAULTS="seed=42;transport-read=drop@0.05;cache-load=error"`.
//!
//! The injection-point inventory lives in `docs/robustness.md`;
//! `unigps-lint` (rule 5) fails CI when a `fault::point!` site is not
//! documented there.

use crate::error::{Result, UniGpsError};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, PoisonError};

/// What an armed failpoint does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Fail the operation with a typed error naming the point.
    Error,
    /// Sleep this many milliseconds, then proceed normally.
    Delay(u64),
    /// Simulate a dropped connection (`ConnectionReset` at I/O sites).
    Drop,
}

impl FaultAction {
    /// Apply at a non-I/O site: `Delay` sleeps and proceeds; `Error` and
    /// `Drop` fail with a typed [`UniGpsError`] naming the point.
    pub fn apply(self, point: &str) -> Result<()> {
        match self {
            FaultAction::Delay(ms) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
            FaultAction::Error => Err(UniGpsError::serve(format!(
                "fault injected at '{point}' (UNIGPS_FAULTS)"
            ))),
            FaultAction::Drop => Err(UniGpsError::ipc(format!(
                "fault injected at '{point}': connection dropped (UNIGPS_FAULTS)"
            ))),
        }
    }

    /// Apply at an I/O site (`Read`/`Write` impls): `Delay` sleeps and
    /// proceeds; `Error` is an `Other` I/O error; `Drop` is
    /// `ConnectionReset`, indistinguishable from a peer vanishing.
    pub fn apply_io(self, point: &str) -> std::io::Result<()> {
        match self {
            FaultAction::Delay(ms) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
            FaultAction::Error => Err(std::io::Error::other(format!(
                "fault injected at '{point}' (UNIGPS_FAULTS)"
            ))),
            FaultAction::Drop => Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                format!("fault injected at '{point}': connection dropped (UNIGPS_FAULTS)"),
            )),
        }
    }
}

/// One armed point: the action, its firing probability and a private hit
/// counter so decisions replay independent of other points' traffic.
#[derive(Debug)]
struct Arm {
    name: String,
    action: FaultAction,
    /// Firing threshold: fire when `mix64(...) < threshold` over the full
    /// `u64` range. `u64::MAX` ≙ probability 1 (always).
    threshold: u64,
    hits: AtomicU64,
}

#[derive(Debug, Default)]
struct Registry {
    seed: u64,
    arms: Vec<Arm>,
}

/// Fast-path gate: false until a non-empty spec is activated. Checked
/// before taking any lock, so disabled failpoints cost one atomic load.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

fn registry() -> std::sync::MutexGuard<'static, Option<Registry>> {
    REGISTRY.lock().unwrap_or_else(PoisonError::into_inner)
}

/// splitmix64 finalizer — the deterministic decision mixer.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Install a parsed registry and flip the fast-path gate accordingly.
fn install(reg: Registry) {
    let enable = !reg.arms.is_empty();
    *registry() = Some(reg);
    ACTIVE.store(enable, Ordering::Release);
}

/// Activate a fault spec, replacing any previous one. Errors are typed
/// `Config` and leave the previous spec in place. Consumes the lazy
/// `UNIGPS_FAULTS` read so a pending environment spec cannot clobber an
/// explicit activation at the next `check`.
pub fn activate(spec: &str) -> Result<()> {
    let reg = parse(spec)?;
    ENV_INIT.call_once(|| {});
    install(reg);
    Ok(())
}

/// Disarm every failpoint (tests call this on their way out so later
/// tests in the same process run clean). Also consumes the lazy
/// `UNIGPS_FAULTS` read: an explicit clear is final — a later `check`
/// must not quietly re-arm from the environment.
pub fn clear() {
    ENV_INIT.call_once(|| {});
    ACTIVE.store(false, Ordering::Release);
    *registry() = None;
}

fn parse(spec: &str) -> Result<Registry> {
    let mut reg = Registry::default();
    for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
        let (name, rhs) = clause.split_once('=').ok_or_else(|| {
            UniGpsError::Config(format!(
                "fault clause '{clause}' is not 'point=action' (UNIGPS_FAULTS)"
            ))
        })?;
        let (name, rhs) = (name.trim(), rhs.trim());
        if name == "seed" {
            reg.seed = rhs.parse().map_err(|_| {
                UniGpsError::Config(format!("fault seed '{rhs}' is not a u64 (UNIGPS_FAULTS)"))
            })?;
            continue;
        }
        let (action_str, prob_str) = match rhs.split_once('@') {
            Some((a, p)) => (a.trim(), Some(p.trim())),
            None => (rhs, None),
        };
        let action = match action_str.split_once(':') {
            Some(("delay", ms)) => FaultAction::Delay(ms.trim().parse().map_err(|_| {
                UniGpsError::Config(format!(
                    "fault delay '{ms}' is not a millisecond count (UNIGPS_FAULTS)"
                ))
            })?),
            None if action_str == "error" => FaultAction::Error,
            None if action_str == "drop" => FaultAction::Drop,
            _ => {
                return Err(UniGpsError::Config(format!(
                    "unknown fault action '{action_str}' for point '{name}' \
                     (expected error | drop | delay:MS)"
                )))
            }
        };
        let threshold = match prob_str {
            None => u64::MAX,
            Some(p) => {
                let p: f64 = p.parse().map_err(|_| {
                    UniGpsError::Config(format!(
                        "fault probability '{p}' is not a number (UNIGPS_FAULTS)"
                    ))
                })?;
                if !(p > 0.0 && p <= 1.0) {
                    return Err(UniGpsError::Config(format!(
                        "fault probability {p} out of (0, 1] for point '{name}'"
                    )));
                }
                if p >= 1.0 {
                    u64::MAX
                } else {
                    (p * (u64::MAX as f64)) as u64
                }
            }
        };
        reg.arms.push(Arm {
            name: name.to_string(),
            action,
            threshold,
            hits: AtomicU64::new(0),
        });
    }
    Ok(reg)
}

/// The macro-facing hook: look `point` up in the active registry and
/// decide (deterministically) whether this hit fires. `None` means
/// proceed normally — including when no spec is active at all, which is
/// the one-atomic-load fast path.
pub fn check(point: &str) -> Option<FaultAction> {
    // The closure must not re-enter ENV_INIT (`activate` consumes it),
    // so it parses and installs directly.
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("UNIGPS_FAULTS") {
            if !spec.is_empty() {
                match parse(&spec) {
                    Ok(reg) => install(reg),
                    Err(e) => eprintln!("unigps: ignoring malformed UNIGPS_FAULTS: {e}"),
                }
            }
        }
    });
    // relaxed: pure gate; the registry lock below orders the real state.
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    let guard = registry();
    let reg = guard.as_ref()?;
    let arm = reg.arms.iter().find(|a| a.name == point)?;
    // relaxed: the counter only feeds the hash; exactness per thread
    // interleaving is not required, uniqueness per hit is.
    let hit = arm.hits.fetch_add(1, Ordering::Relaxed);
    let roll = mix64(fnv1a(&arm.name) ^ reg.seed.wrapping_mul(0x2545_f491_4f6c_dd1d) ^ hit);
    if arm.threshold == u64::MAX || roll < arm.threshold {
        Some(arm.action)
    } else {
        None
    }
}

/// Mark a named failpoint. Expands to [`check`]`("name")`, returning
/// `Option<FaultAction>` — `None` (overwhelmingly, and always in
/// production) means proceed. Call sites pair it with
/// [`FaultAction::apply`] or [`FaultAction::apply_io`]:
///
/// ```ignore
/// if let Some(act) = fault::point!("cache-load") {
///     act.apply("cache-load")?;
/// }
/// ```
///
/// Every site name must be listed in the injection-point inventory in
/// `docs/robustness.md` — `unigps-lint` rule 5 enforces this.
#[doc(hidden)]
#[macro_export]
macro_rules! __unigps_fault_point {
    ($name:literal) => {
        $crate::util::fault::check($name)
    };
}

pub use crate::__unigps_fault_point as point;

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; these tests serialize on a lock so
    // activations never interleave.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn disabled_points_are_none() {
        let _g = locked();
        clear();
        assert_eq!(check("anything"), None);
    }

    #[test]
    fn error_drop_and_delay_parse_and_fire() {
        let _g = locked();
        activate("a=error;b=drop;c=delay:1").unwrap();
        assert_eq!(check("a"), Some(FaultAction::Error));
        assert_eq!(check("b"), Some(FaultAction::Drop));
        assert_eq!(check("c"), Some(FaultAction::Delay(1)));
        assert_eq!(check("unarmed"), None);
        clear();
        assert_eq!(check("a"), None);
    }

    #[test]
    fn probability_decisions_replay_exactly() {
        let _g = locked();
        let observe = || -> Vec<bool> {
            activate("seed=7;p=error@0.5").unwrap();
            (0..64).map(|_| check("p").is_some()).collect()
        };
        let first = observe();
        let second = observe();
        assert_eq!(first, second, "same spec must replay the same schedule");
        let fired = first.iter().filter(|f| **f).count();
        assert!(fired > 0 && fired < 64, "p=0.5 over 64 hits fired {fired}");
        // A different seed is a different (still deterministic) schedule.
        activate("seed=8;p=error@0.5").unwrap();
        let third: Vec<bool> = (0..64).map(|_| check("p").is_some()).collect();
        assert_ne!(first, third, "seed must steer the schedule");
        clear();
    }

    #[test]
    fn malformed_specs_are_typed_config_errors() {
        let _g = locked();
        clear();
        for bad in [
            "nonsense",
            "x=explode",
            "x=delay:soon",
            "x=error@1.5",
            "x=error@0",
            "x=error@maybe",
            "seed=minus-one",
        ] {
            let err = activate(bad).unwrap_err();
            assert!(
                matches!(err, UniGpsError::Config(_)),
                "{bad:?} gave {err:?}"
            );
        }
        // A failed activation never arms anything.
        assert_eq!(check("x"), None);
    }

    #[test]
    fn typed_errors_name_the_point() {
        let err = FaultAction::Error.apply("cache-load").unwrap_err();
        assert!(err.to_string().contains("cache-load"), "{err}");
        let err = FaultAction::Drop.apply_io("transport-read").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::ConnectionReset);
        assert!(FaultAction::Delay(0).apply("x").is_ok());
        assert!(FaultAction::Delay(0).apply_io("x").is_ok());
    }
}
