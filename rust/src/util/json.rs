//! Minimal JSON reader/writer (serde_json is unavailable offline).
//!
//! Supports the subset the unified graph I/O format needs: objects, arrays,
//! strings (with escapes), integers, floats, booleans and null. Numbers are
//! kept as `i64` when lossless, else `f64` — matching the [`crate::graph::record::Value`]
//! type system.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Integer (lossless i64)
    Int(i64),
    /// Floating point
    Float(f64),
    /// String
    Str(String),
    /// Array
    Array(Vec<Json>),
    /// Object (sorted keys for deterministic output)
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Get `self` as an object map.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Get `self` as an array.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Get `self` as an i64 (ints only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Get `self` as f64 (accepts ints).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Json::Float(f) => Some(*f),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Get `self` as a string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Get `self` as a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    // Ensure round-trip: always include a decimal point or exponent.
                    let s = format!("{f}");
                    out.push_str(&s);
                    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
                        out.push_str(".0");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document from a string.
    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err("bad \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| "bad \\u escape")?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        self.pos += 4;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(b) => {
                    // Re-decode UTF-8: collect continuation bytes.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| "invalid utf8 in string")?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Json::Float)
                .map_err(|e| format!("bad float '{text}': {e}"))
        } else {
            text.parse::<i64>()
                .map(Json::Int)
                .map_err(|e| format!("bad int '{text}': {e}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("2.5").unwrap(), Json::Float(2.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Float(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_int(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"edges":[{"dst":2,"src":1,"w":0.5}],"name":"g","n":10}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\té\u{1}".into());
        let enc = s.to_string();
        assert_eq!(Json::parse(&enc).unwrap(), s);
    }

    #[test]
    fn float_output_reparses_as_float() {
        let v = Json::Float(3.0);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), Json::Float(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }
}
