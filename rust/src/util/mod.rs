//! Small self-contained utilities.
//!
//! The offline build environment ships only the `xla` crate closure, so this
//! module re-implements the handful of third-party conveniences the rest of
//! the crate needs: a seedable PRNG ([`rng`]), timing helpers ([`timer`]), a
//! micro-benchmark harness ([`crate::util::bench`], criterion stand-in), a minimal
//! property-based testing harness ([`propcheck`], proptest stand-in) and a
//! small JSON reader/writer ([`json`], serde_json stand-in).

pub mod bench;
pub mod fault;
pub mod json;
pub mod model;
pub mod propcheck;
pub mod rng;
pub mod sync;
pub mod timer;

/// Format a byte count with binary units.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a large count with thousands separators (`1_234_567`).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }
}
