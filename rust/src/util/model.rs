//! In-house schedule-exploring model checker (the engine behind
//! [`crate::util::sync`]).
//!
//! The crate's lock-free runtime — FlatBoard seal epochs, the superstep
//! counting gates, the serve scheduler's condvars — rests on hand-reasoned
//! release/acquire protocols. This module makes those protocols checkable
//! without any third-party dependency (no loom, no shuttle): a test wraps
//! its threads in an [`Explorer`], each thread registers with the per-run
//! [`Session`], and every operation on the instrumented sync types below
//! becomes a *scheduling point* where a deterministic virtual scheduler
//! decides which thread runs next.
//!
//! ## How scheduling works
//!
//! Real OS threads take turns under a single token. At every instrumented
//! operation the running thread calls back into the scheduler, which picks
//! the next thread to run — either pseudo-randomly from a per-schedule seed
//! ([`Strategy::Random`]) or by depth-first enumeration of every choice
//! sequence ([`Strategy::Exhaustive`], for tiny spin-free protocols). Every
//! thread is always runnable: the model [`Mutex`] spins on `try_lock` under
//! the token, [`Condvar::wait`] is modeled as a legal spurious wakeup
//! (unlock → reschedule → relock), and lost-progress bugs surface as a
//! per-schedule step-budget exhaustion instead of a hang.
//!
//! ## How race detection works
//!
//! Every thread carries a vector clock. Release stores publish the writer's
//! clock on the atomic; acquire loads join it; **`Relaxed` accesses carry no
//! clock** — which is exactly what makes a wrongly-relaxed publication
//! detectable. Plain (non-atomic) accesses that the protocol is supposed to
//! protect are declared with [`trace_write`]/[`trace_read`]; the checker
//! keeps FastTrack-style read/write vectors per location and reports a data
//! race whenever an access is not ordered after every previous conflicting
//! access.
//!
//! The model is sequentially consistent over atomic *values* (weak-memory
//! value reordering is out of scope — a documented limitation, see
//! `docs/concurrency.md`); what it explores exhaustively is interleaving
//! nondeterminism, and what it verifies is the happens-before structure the
//! orderings are supposed to build.
//!
//! This module is always compiled (the smoke tests in
//! `rust/tests/model_check.rs` drive protocol replicas against these types
//! directly); the `unigps_model` cfg only controls whether
//! [`crate::util::sync`] re-exports these types in place of `std`'s.
#![warn(missing_docs)]

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::mem::ManuallyDrop;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{
    Arc, Condvar as StdCondvar, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard, Once,
    PoisonError, TryLockError,
};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Vector clocks
// ---------------------------------------------------------------------------

type Clock = Vec<u64>;

fn join_into(into: &mut Clock, other: &[u64]) {
    if into.len() < other.len() {
        into.resize(other.len(), 0);
    }
    for (i, &v) in other.iter().enumerate() {
        if into[i] < v {
            into[i] = v;
        }
    }
}

/// `a ≤ c` pointwise (missing entries are zero).
fn dominated(a: &[u64], c: &[u64]) -> bool {
    a.iter().enumerate().all(|(i, &v)| v <= c.get(i).copied().unwrap_or(0))
}

fn acquires(ord: Ordering) -> bool {
    matches!(ord, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

fn releases(ord: Ordering) -> bool {
    matches!(ord, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// The virtual scheduler
// ---------------------------------------------------------------------------

enum Choice {
    /// xorshift64 state; one stream per schedule.
    Random(u64),
    /// Depth-first enumeration: replay this choice prefix, then take the
    /// first option at every new depth.
    Exhaustive { replay: Vec<usize> },
}

struct SchedInner {
    expected: usize,
    registered: usize,
    alive: Vec<bool>,
    started: bool,
    current: usize,
    steps: u64,
    budget: u64,
    abort: Option<String>,
    /// Every choice made this schedule, as `(chosen, n_options)`.
    trace: Vec<(usize, usize)>,
    choice: Choice,
    schedule_hash: u64,
    clocks: Vec<Clock>,
    /// Traced plain-memory locations: address → (write clock, read clock).
    locs: HashMap<usize, (Clock, Clock)>,
}

impl SchedInner {
    fn fail(&mut self, msg: String) {
        if self.abort.is_none() {
            self.abort = Some(msg);
        }
    }

    fn runnable(&self) -> Vec<usize> {
        self.alive
            .iter()
            .enumerate()
            .filter_map(|(i, &a)| if a { Some(i) } else { None })
            .collect()
    }

    /// Make (and record) one scheduling choice among `n` options.
    fn choose(&mut self, n: usize) -> usize {
        let depth = self.trace.len();
        let c = match &mut self.choice {
            Choice::Random(state) => {
                if n <= 1 {
                    0
                } else {
                    *state ^= *state << 13;
                    *state ^= *state >> 7;
                    *state ^= *state << 17;
                    (*state % n as u64) as usize
                }
            }
            Choice::Exhaustive { replay } => replay.get(depth).copied().unwrap_or(0),
        };
        let c = c.min(n.saturating_sub(1));
        self.trace.push((c, n));
        self.schedule_hash = self
            .schedule_hash
            .wrapping_mul(0x0000_0100_0000_01b3)
            .wrapping_add(((c as u64) << 8) | n as u64);
        c
    }
}

/// Panic payload used to unwind a thread out of an aborted schedule. The
/// [`Explorer`] installs a panic hook that keeps these quiet.
struct ModelAbort;

fn abort_schedule() -> ! {
    panic::panic_any(ModelAbort)
}

/// How long a thread waits on the token condvar before suspecting the model
/// itself is stuck (a backstop against checker bugs, not a protocol timeout).
const WAIT_SLICE: Duration = Duration::from_millis(50);
const WAIT_DEADLINE_SLICES: u32 = 200;

/// One model-checking run: the token-passing scheduler plus all per-run
/// state (vector clocks, traced locations, the choice trace).
///
/// Created by [`Explorer::run`] and handed to the test body, which spawns
/// its scoped threads and has each call [`Session::register`].
pub struct Session {
    inner: StdMutex<SchedInner>,
    cv: StdCondvar,
}

struct Ctx {
    sess: Arc<Session>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

fn current_ctx() -> Option<(Arc<Session>, usize)> {
    CTX.with(|c| c.borrow().as_ref().map(|x| (Arc::clone(&x.sess), x.tid)))
}

impl Session {
    fn new(threads: usize, budget: u64, choice: Choice) -> Session {
        let clocks = (0..threads)
            .map(|t| {
                let mut c = vec![0; threads];
                c[t] = 1;
                c
            })
            .collect();
        Session {
            inner: StdMutex::new(SchedInner {
                expected: threads,
                registered: 0,
                alive: vec![false; threads],
                started: false,
                current: 0,
                steps: 0,
                budget,
                abort: None,
                trace: Vec::new(),
                choice,
                schedule_hash: 0xcbf2_9ce4_8422_2325,
                clocks,
                locs: HashMap::new(),
            }),
            cv: StdCondvar::new(),
        }
    }

    fn lock_inner(&self) -> StdMutexGuard<'_, SchedInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Enter the model as worker `tid` (0-based, unique per thread). Blocks
    /// until all expected workers have registered, then returns a guard
    /// whose `Drop` deregisters the thread and hands the token on — so a
    /// panicking worker never wedges its siblings.
    pub fn register(self: &Arc<Session>, tid: usize) -> Registration {
        CTX.with(|c| {
            *c.borrow_mut() = Some(Ctx { sess: Arc::clone(self), tid });
        });
        let mut g = self.lock_inner();
        if tid >= g.expected || g.alive[tid] {
            g.fail(format!("bad or duplicate registration of model worker {tid}"));
            drop(g);
            self.cv.notify_all();
            abort_schedule();
        }
        g.alive[tid] = true;
        g.registered += 1;
        if g.registered == g.expected {
            g.started = true;
            let opts = g.runnable();
            let i = g.choose(opts.len());
            g.current = opts[i];
            self.cv.notify_all();
        }
        let mut slices = 0u32;
        while !(g.started && g.current == tid) {
            if g.abort.is_some() {
                drop(g);
                self.cv.notify_all();
                abort_schedule();
            }
            let (ng, to) = self
                .cv
                .wait_timeout(g, WAIT_SLICE)
                .unwrap_or_else(PoisonError::into_inner);
            g = ng;
            if to.timed_out() {
                slices += 1;
                if slices > WAIT_DEADLINE_SLICES {
                    g.fail("model scheduler stalled during registration".to_string());
                    drop(g);
                    self.cv.notify_all();
                    abort_schedule();
                }
            }
        }
        Registration { _priv: () }
    }

    fn sync_acquire(&self, tid: usize, sync: &StdMutex<Clock>) {
        let mut g = self.lock_inner();
        let s = sync.lock().unwrap_or_else(PoisonError::into_inner);
        join_into(&mut g.clocks[tid], &s);
    }

    /// Clock effect of a plain atomic store: a release publishes the
    /// writer's clock; anything weaker erases the location's clock — there
    /// is no happens-before edge for a later acquire to pick up.
    fn sync_store(&self, tid: usize, sync: &StdMutex<Clock>, ord: Ordering) {
        let mut g = self.lock_inner();
        let mut s = sync.lock().unwrap_or_else(PoisonError::into_inner);
        if releases(ord) {
            *s = g.clocks[tid].clone();
            g.clocks[tid][tid] += 1;
        } else {
            s.clear();
        }
    }

    /// Clock effect of a read-modify-write. Unlike a store, a relaxed RMW
    /// *keeps* the location's clock: it continues the release sequence
    /// headed by the last release store (C++20 §intro.races), which is what
    /// lets relaxed `fetch_add` chains on a gate stay sound when the gate
    /// value itself is published by a release op.
    fn sync_rmw(&self, tid: usize, sync: &StdMutex<Clock>, ord: Ordering) {
        let mut g = self.lock_inner();
        let mut s = sync.lock().unwrap_or_else(PoisonError::into_inner);
        if acquires(ord) {
            join_into(&mut g.clocks[tid], &s);
        }
        if releases(ord) {
            let snapshot = g.clocks[tid].clone();
            join_into(&mut s, &snapshot);
            g.clocks[tid][tid] += 1;
        }
    }
}

/// Guard returned by [`Session::register`]; dropping it (normally or during
/// a panic) deregisters the worker and hands the token to a live sibling.
pub struct Registration {
    _priv: (),
}

impl Drop for Registration {
    fn drop(&mut self) {
        let ctx = CTX.with(|c| c.borrow_mut().take());
        if let Some(ctx) = ctx {
            let mut g = ctx.sess.lock_inner();
            if ctx.tid < g.alive.len() && g.alive[ctx.tid] {
                g.alive[ctx.tid] = false;
                if g.current == ctx.tid {
                    if let Some(next) = g.alive.iter().position(|&a| a) {
                        g.current = next;
                    }
                }
            }
            drop(g);
            ctx.sess.cv.notify_all();
        }
    }
}

/// The heart of the model: every instrumented operation lands here. Counts
/// the step against the schedule budget, picks the next thread to run, and
/// blocks until the token comes back (or the schedule aborts).
fn yield_point(sess: &Arc<Session>, tid: usize) {
    let mut g = sess.lock_inner();
    if g.abort.is_some() {
        drop(g);
        sess.cv.notify_all();
        abort_schedule();
    }
    g.steps += 1;
    if g.steps > g.budget {
        let budget = g.budget;
        g.fail(format!(
            "schedule budget of {budget} steps exhausted (livelock, deadlock, or unbounded spin)"
        ));
        drop(g);
        sess.cv.notify_all();
        abort_schedule();
    }
    let opts = g.runnable();
    if opts.is_empty() {
        return;
    }
    let i = g.choose(opts.len());
    let next = opts[i];
    if next != tid {
        g.current = next;
        sess.cv.notify_all();
        let mut slices = 0u32;
        while g.current != tid {
            if g.abort.is_some() {
                drop(g);
                sess.cv.notify_all();
                abort_schedule();
            }
            let (ng, to) = sess
                .cv
                .wait_timeout(g, WAIT_SLICE)
                .unwrap_or_else(PoisonError::into_inner);
            g = ng;
            if to.timed_out() {
                slices += 1;
                if slices > WAIT_DEADLINE_SLICES {
                    g.fail("model scheduler stalled waiting for the token".to_string());
                    drop(g);
                    sess.cv.notify_all();
                    abort_schedule();
                }
            }
        }
    }
    if g.abort.is_some() {
        drop(g);
        sess.cv.notify_all();
        abort_schedule();
    }
}

// ---------------------------------------------------------------------------
// Traced plain-memory accesses (FastTrack-style race detection)
// ---------------------------------------------------------------------------

/// Declare a plain (non-atomic) write to `addr` that the surrounding
/// protocol is supposed to order. Outside a model session this is a no-op;
/// inside one it is a scheduling point plus a race check: the write must
/// happen-after every previous read *and* write of the same address.
pub fn trace_write(addr: usize) {
    if let Some((s, t)) = current_ctx() {
        yield_point(&s, t);
        let mut g = s.lock_inner();
        let inner = &mut *g;
        let me = &inner.clocks[t];
        let epoch = me[t];
        let (w, r) = inner.locs.entry(addr).or_default();
        if !(dominated(w, me) && dominated(r, me)) {
            inner.fail(format!(
                "data race: unsynchronized write to traced location {addr:#x} by worker {t}"
            ));
            drop(g);
            s.cv.notify_all();
            abort_schedule();
        }
        let (w, _) = inner.locs.entry(addr).or_default();
        if w.len() <= t {
            w.resize(t + 1, 0);
        }
        w[t] = epoch;
    }
}

/// Declare a plain (non-atomic) read of `addr`; must happen-after every
/// previous write of the same address. No-op outside a model session.
pub fn trace_read(addr: usize) {
    if let Some((s, t)) = current_ctx() {
        yield_point(&s, t);
        let mut g = s.lock_inner();
        let inner = &mut *g;
        let me = &inner.clocks[t];
        let epoch = me[t];
        let (w, _) = inner.locs.entry(addr).or_default();
        if !dominated(w, me) {
            inner.fail(format!(
                "data race: unsynchronized read of traced location {addr:#x} by worker {t}"
            ));
            drop(g);
            s.cv.notify_all();
            abort_schedule();
        }
        let (_, r) = inner.locs.entry(addr).or_default();
        if r.len() <= t {
            r.resize(t + 1, 0);
        }
        r[t] = epoch;
    }
}

// ---------------------------------------------------------------------------
// Instrumented atomics
// ---------------------------------------------------------------------------

macro_rules! model_int_atomic {
    ($(#[$doc:meta])* $name:ident, $std:ident, $ty:ty) => {
        $(#[$doc])*
        pub struct $name {
            v: std::sync::atomic::$std,
            sync: StdMutex<Clock>,
        }

        impl $name {
            /// Create with an initial value.
            pub const fn new(v: $ty) -> Self {
                Self { v: std::sync::atomic::$std::new(v), sync: StdMutex::new(Vec::new()) }
            }

            /// Atomic load; an acquire joins the location's published clock.
            pub fn load(&self, ord: Ordering) -> $ty {
                match current_ctx() {
                    Some((s, t)) => {
                        yield_point(&s, t);
                        let v = self.v.load(Ordering::SeqCst);
                        if acquires(ord) {
                            s.sync_acquire(t, &self.sync);
                        }
                        v
                    }
                    None => self.v.load(ord),
                }
            }

            /// Atomic store; a release publishes the writer's clock, weaker
            /// orderings erase it.
            pub fn store(&self, v: $ty, ord: Ordering) {
                match current_ctx() {
                    Some((s, t)) => {
                        yield_point(&s, t);
                        self.v.store(v, Ordering::SeqCst);
                        s.sync_store(t, &self.sync, ord);
                    }
                    None => self.v.store(v, ord),
                }
            }

            /// Atomic swap (read-modify-write clock semantics).
            pub fn swap(&self, v: $ty, ord: Ordering) -> $ty {
                match current_ctx() {
                    Some((s, t)) => {
                        yield_point(&s, t);
                        let old = self.v.swap(v, Ordering::SeqCst);
                        s.sync_rmw(t, &self.sync, ord);
                        old
                    }
                    None => self.v.swap(v, ord),
                }
            }

            /// Atomic add, returning the previous value.
            pub fn fetch_add(&self, d: $ty, ord: Ordering) -> $ty {
                match current_ctx() {
                    Some((s, t)) => {
                        yield_point(&s, t);
                        let old = self.v.fetch_add(d, Ordering::SeqCst);
                        s.sync_rmw(t, &self.sync, ord);
                        old
                    }
                    None => self.v.fetch_add(d, ord),
                }
            }

            /// Atomic bitwise or, returning the previous value.
            pub fn fetch_or(&self, d: $ty, ord: Ordering) -> $ty {
                match current_ctx() {
                    Some((s, t)) => {
                        yield_point(&s, t);
                        let old = self.v.fetch_or(d, Ordering::SeqCst);
                        s.sync_rmw(t, &self.sync, ord);
                        old
                    }
                    None => self.v.fetch_or(d, ord),
                }
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}({})", stringify!($name), self.v.load(Ordering::SeqCst))
            }
        }
    };
}

model_int_atomic!(
    /// Model-checked stand-in for [`std::sync::atomic::AtomicU64`].
    AtomicU64,
    AtomicU64,
    u64
);
model_int_atomic!(
    /// Model-checked stand-in for [`std::sync::atomic::AtomicUsize`].
    AtomicUsize,
    AtomicUsize,
    usize
);
model_int_atomic!(
    /// Model-checked stand-in for [`std::sync::atomic::AtomicU32`].
    AtomicU32,
    AtomicU32,
    u32
);

/// Model-checked stand-in for [`std::sync::atomic::AtomicBool`].
pub struct AtomicBool {
    v: std::sync::atomic::AtomicBool,
    sync: StdMutex<Clock>,
}

impl AtomicBool {
    /// Create with an initial value.
    pub const fn new(v: bool) -> Self {
        Self { v: std::sync::atomic::AtomicBool::new(v), sync: StdMutex::new(Vec::new()) }
    }

    /// Atomic load; an acquire joins the location's published clock.
    pub fn load(&self, ord: Ordering) -> bool {
        match current_ctx() {
            Some((s, t)) => {
                yield_point(&s, t);
                let v = self.v.load(Ordering::SeqCst);
                if acquires(ord) {
                    s.sync_acquire(t, &self.sync);
                }
                v
            }
            None => self.v.load(ord),
        }
    }

    /// Atomic store; a release publishes the writer's clock.
    pub fn store(&self, v: bool, ord: Ordering) {
        match current_ctx() {
            Some((s, t)) => {
                yield_point(&s, t);
                self.v.store(v, Ordering::SeqCst);
                s.sync_store(t, &self.sync, ord);
            }
            None => self.v.store(v, ord),
        }
    }

    /// Atomic swap (read-modify-write clock semantics).
    pub fn swap(&self, v: bool, ord: Ordering) -> bool {
        match current_ctx() {
            Some((s, t)) => {
                yield_point(&s, t);
                let old = self.v.swap(v, Ordering::SeqCst);
                s.sync_rmw(t, &self.sync, ord);
                old
            }
            None => self.v.swap(v, ord),
        }
    }
}

impl std::fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AtomicBool({})", self.v.load(Ordering::SeqCst))
    }
}

// ---------------------------------------------------------------------------
// Instrumented Mutex / Condvar / Barrier
// ---------------------------------------------------------------------------

/// Model-checked stand-in for [`std::sync::Mutex`]. Under a session the
/// lock spins on `try_lock` at scheduling points (every thread stays
/// runnable; a real deadlock surfaces as budget exhaustion); outside a
/// session it behaves exactly like `std`'s.
pub struct Mutex<T: ?Sized> {
    sync: StdMutex<Clock>,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// Create a new mutex.
    pub const fn new(v: T) -> Self {
        Self { sync: StdMutex::new(Vec::new()), inner: StdMutex::new(v) }
    }

    /// Consume the mutex, returning the inner value.
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    /// Acquire the lock (see type docs for model semantics).
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match current_ctx() {
            Some((s, t)) => loop {
                yield_point(&s, t);
                match self.inner.try_lock() {
                    Ok(g) => {
                        s.sync_acquire(t, &self.sync);
                        return Ok(MutexGuard { g: ManuallyDrop::new(g), lock: self });
                    }
                    Err(TryLockError::WouldBlock) => continue,
                    Err(TryLockError::Poisoned(p)) => {
                        s.sync_acquire(t, &self.sync);
                        let g = MutexGuard { g: ManuallyDrop::new(p.into_inner()), lock: self };
                        return Err(PoisonError::new(g));
                    }
                }
            },
            None => match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { g: ManuallyDrop::new(g), lock: self }),
                Err(p) => {
                    let g = MutexGuard { g: ManuallyDrop::new(p.into_inner()), lock: self };
                    Err(PoisonError::new(g))
                }
            },
        }
    }
}

/// Guard for the model [`Mutex`]; unlocking publishes the holder's clock
/// (lock/unlock are release/acquire pairs, as in the real thing).
pub struct MutexGuard<'a, T: ?Sized> {
    g: ManuallyDrop<StdMutexGuard<'a, T>>,
    lock: &'a Mutex<T>,
}

impl<'a, T> MutexGuard<'a, T> {
    fn into_std(mut self) -> (StdMutexGuard<'a, T>, &'a Mutex<T>) {
        // SAFETY: the guard is taken exactly once; `self` is forgotten
        // immediately after, so `Drop` never sees the emptied slot.
        let g = unsafe { ManuallyDrop::take(&mut self.g) };
        let lock = self.lock;
        std::mem::forget(self);
        (g, lock)
    }

    fn from_std(g: StdMutexGuard<'a, T>, lock: &'a Mutex<T>) -> Self {
        MutexGuard { g: ManuallyDrop::new(g), lock }
    }
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.g
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.g
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((s, t)) = current_ctx() {
            // Unlock is a release: publish, never panic (this may run
            // during unwinding).
            s.sync_store(t, &self.lock.sync, Ordering::Release);
        }
        // SAFETY: `into_std` forgets `self`, so when `drop` runs the slot
        // still holds the guard and this is its only drop.
        unsafe { ManuallyDrop::drop(&mut self.g) }
    }
}

/// Result of [`Condvar::wait_timeout`] (mirrors
/// [`std::sync::WaitTimeoutResult`], which cannot be constructed outside
/// `std`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// True when the wait ended by timeout rather than notification.
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Model-checked stand-in for [`std::sync::Condvar`]. Under a session,
/// `wait` is modeled as a spurious wakeup — unlock, reschedule, relock —
/// which is a legal behavior of the real condvar, so any protocol correct
/// under the model's waits is correct under `std`'s (waiters must recheck
/// their predicate either way).
pub struct Condvar {
    cv: StdCondvar,
}

impl Condvar {
    /// Create a new condition variable.
    pub const fn new() -> Self {
        Self { cv: StdCondvar::new() }
    }

    /// Wait (model: spurious wakeup; see type docs).
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match current_ctx() {
            Some(_) => {
                let lock = guard.lock;
                drop(guard);
                lock.lock()
            }
            None => {
                let (g, lock) = guard.into_std();
                match self.cv.wait(g) {
                    Ok(g) => Ok(MutexGuard::from_std(g, lock)),
                    Err(p) => Err(PoisonError::new(MutexGuard::from_std(p.into_inner(), lock))),
                }
            }
        }
    }

    /// Wait with a timeout (model: immediate spurious wakeup, not timed
    /// out — callers recheck predicates and deadlines themselves).
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match current_ctx() {
            Some(_) => {
                let lock = guard.lock;
                drop(guard);
                match lock.lock() {
                    Ok(g) => Ok((g, WaitTimeoutResult(false))),
                    Err(p) => Err(PoisonError::new((p.into_inner(), WaitTimeoutResult(false)))),
                }
            }
            None => {
                let (g, lock) = guard.into_std();
                match self.cv.wait_timeout(g, dur) {
                    Ok((g, to)) => Ok((
                        MutexGuard::from_std(g, lock),
                        WaitTimeoutResult(to.timed_out()),
                    )),
                    Err(p) => {
                        let (g, to) = p.into_inner();
                        Err(PoisonError::new((
                            MutexGuard::from_std(g, lock),
                            WaitTimeoutResult(to.timed_out()),
                        )))
                    }
                }
            }
        }
    }

    /// Wake one waiter (no-op under the model: waits are spurious).
    pub fn notify_one(&self) {
        self.cv.notify_one();
    }

    /// Wake all waiters (no-op under the model: waits are spurious).
    pub fn notify_all(&self) {
        self.cv.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

/// Result of [`Barrier::wait`] (mirrors [`std::sync::BarrierWaitResult`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BarrierWaitResult(bool);

impl BarrierWaitResult {
    /// True for exactly one arriver per barrier generation.
    pub fn is_leader(&self) -> bool {
        self.0
    }
}

struct BarrierState {
    count: usize,
    generation: u64,
}

/// Model-checked stand-in for [`std::sync::Barrier`]. Under a session,
/// non-leaders spin on the generation counter at scheduling points; the
/// barrier is a full release/acquire rendezvous (everyone's clock joins
/// everyone's), exactly like the real thing.
pub struct Barrier {
    n: usize,
    st: StdMutex<BarrierState>,
    cv: StdCondvar,
    sync: StdMutex<Clock>,
}

impl Barrier {
    /// Create a barrier for `n` participants.
    pub fn new(n: usize) -> Self {
        Self {
            n: n.max(1),
            st: StdMutex::new(BarrierState { count: 0, generation: 0 }),
            cv: StdCondvar::new(),
            sync: StdMutex::new(Vec::new()),
        }
    }

    /// Arrive and wait for the full cohort.
    pub fn wait(&self) -> BarrierWaitResult {
        match current_ctx() {
            Some((s, t)) => {
                yield_point(&s, t);
                // Publish my clock into the barrier and take a ticket.
                {
                    let g = s.lock_inner();
                    let mut sy = self.sync.lock().unwrap_or_else(PoisonError::into_inner);
                    join_into(&mut sy, &g.clocks[t]);
                }
                let (gen, leader) = {
                    let mut st = self.st.lock().unwrap_or_else(PoisonError::into_inner);
                    st.count += 1;
                    let leader = st.count == self.n;
                    let gen = st.generation;
                    if leader {
                        st.count = 0;
                        st.generation += 1;
                        self.cv.notify_all();
                    }
                    (gen, leader)
                };
                if !leader {
                    loop {
                        yield_point(&s, t);
                        let st = self.st.lock().unwrap_or_else(PoisonError::into_inner);
                        if st.generation != gen {
                            break;
                        }
                    }
                }
                // Acquire the cohort's merged clock and start a new epoch.
                {
                    let mut g = s.lock_inner();
                    let sy = self.sync.lock().unwrap_or_else(PoisonError::into_inner);
                    join_into(&mut g.clocks[t], &sy);
                    g.clocks[t][t] += 1;
                }
                BarrierWaitResult(leader)
            }
            None => {
                let mut st = self.st.lock().unwrap_or_else(PoisonError::into_inner);
                let gen = st.generation;
                st.count += 1;
                if st.count == self.n {
                    st.count = 0;
                    st.generation += 1;
                    self.cv.notify_all();
                    BarrierWaitResult(true)
                } else {
                    while st.generation == gen {
                        st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                    }
                    BarrierWaitResult(false)
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The explorer
// ---------------------------------------------------------------------------

/// Exploration strategy for [`Explorer`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Seeded pseudo-random choice at every scheduling point; each schedule
    /// gets an independent stream derived from the base seed.
    Random,
    /// Depth-first enumeration of *every* choice sequence. Only for tiny,
    /// spin-free protocols — spinning makes the choice tree infinite.
    Exhaustive,
}

/// Outcome of an [`Explorer::run`].
#[derive(Debug)]
pub struct Report {
    /// Schedules actually executed.
    pub schedules_run: usize,
    /// Distinct choice sequences among them (hash-based).
    pub distinct_schedules: usize,
    /// One entry per failing schedule: detected data races, assertion
    /// failures inside the test body, budget exhaustion.
    pub failures: Vec<String>,
    /// True when exhaustive exploration enumerated the full tree.
    pub complete: bool,
}

impl Report {
    /// Panic with the collected failures unless every schedule passed.
    pub fn assert_clean(&self) {
        assert!(
            self.failures.is_empty(),
            "{} of {} schedules failed; first: {}",
            self.failures.len(),
            self.schedules_run,
            self.failures.first().map(String::as_str).unwrap_or("")
        );
    }
}

/// Drives a closure through many schedules, one fresh [`Session`] each.
///
/// ```
/// use unigps::util::model::{AtomicU64, Explorer};
/// use std::sync::atomic::Ordering;
///
/// let report = Explorer::new(2).schedules(64).run(|sess| {
///     let counter = AtomicU64::new(0);
///     std::thread::scope(|s| {
///         for tid in 0..2 {
///             let counter = &counter;
///             s.spawn(move || {
///                 let _reg = sess.register(tid);
///                 counter.fetch_add(1, Ordering::AcqRel);
///             });
///         }
///     });
///     assert_eq!(counter.load(Ordering::Acquire), 2);
/// });
/// report.assert_clean();
/// ```
pub struct Explorer {
    threads: usize,
    schedules: usize,
    seed: u64,
    budget: u64,
    strategy: Strategy,
}

impl Explorer {
    /// Explore protocols among `threads` registered workers. Defaults:
    /// 256 random schedules, 200k steps each.
    pub fn new(threads: usize) -> Self {
        Explorer {
            threads,
            schedules: 256,
            seed: 0x9e37_79b9_7f4a_7c15,
            budget: 200_000,
            strategy: Strategy::Random,
        }
    }

    /// Set the maximum number of schedules to run.
    pub fn schedules(mut self, n: usize) -> Self {
        self.schedules = n.max(1);
        self
    }

    /// Set the base seed for random exploration.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the per-schedule step budget.
    pub fn budget(mut self, steps: u64) -> Self {
        self.budget = steps.max(1);
        self
    }

    /// Switch to bounded exhaustive (DFS) exploration.
    pub fn exhaustive(mut self) -> Self {
        self.strategy = Strategy::Exhaustive;
        self
    }

    /// Run `body` once per schedule. The body must spawn and *join* (e.g.
    /// via [`std::thread::scope`]) exactly `threads` workers, each of which
    /// calls [`Session::register`] with a unique id.
    pub fn run<F: Fn(&Arc<Session>)>(&self, body: F) -> Report {
        install_quiet_abort_hook();
        let mut seen = HashSet::new();
        let mut failures = Vec::new();
        let mut replay: Vec<usize> = Vec::new();
        let mut complete = false;
        let mut runs = 0;
        for i in 0..self.schedules {
            let choice = match self.strategy {
                Strategy::Random => {
                    // `| 1` keeps the xorshift stream out of its zero fixpoint.
                    Choice::Random(splitmix64(self.seed.wrapping_add(i as u64)) | 1)
                }
                Strategy::Exhaustive => Choice::Exhaustive { replay: replay.clone() },
            };
            let sess = Arc::new(Session::new(self.threads, self.budget, choice));
            let out = panic::catch_unwind(AssertUnwindSafe(|| body(&sess)));
            runs += 1;
            let g = sess.lock_inner();
            seen.insert(g.schedule_hash);
            match out {
                Ok(()) => {
                    if let Some(msg) = &g.abort {
                        failures.push(format!("schedule {i}: {msg}"));
                    }
                }
                Err(payload) => {
                    let msg = match &g.abort {
                        Some(m) => m.clone(),
                        None => describe_panic(payload.as_ref()),
                    };
                    failures.push(format!("schedule {i}: {msg}"));
                }
            }
            if self.strategy == Strategy::Exhaustive {
                match next_replay(&g.trace) {
                    Some(next) => replay = next,
                    None => {
                        complete = true;
                        drop(g);
                        break;
                    }
                }
            }
        }
        Report { schedules_run: runs, distinct_schedules: seen.len(), failures, complete }
    }
}

/// Advance the DFS odometer: bump the deepest incrementable choice, drop
/// everything after it. `None` when the tree is exhausted.
fn next_replay(trace: &[(usize, usize)]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        let (c, n) = trace[i];
        if c + 1 < n {
            let mut r: Vec<usize> = trace[..i].iter().map(|&(c, _)| c).collect();
            r.push(c + 1);
            return Some(r);
        }
    }
    None
}

fn describe_panic(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic in model schedule".to_string()
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

static QUIET_HOOK: Once = Once::new();

/// `ModelAbort` panics are control flow, not failures; keep the default
/// hook from spraying a backtrace per aborted schedule. Installed once,
/// chains to the previous hook for every real panic.
fn install_quiet_abort_hook() {
    QUIET_HOOK.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<ModelAbort>().is_none() {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn clock_join_and_domination() {
        let mut a = vec![1, 0];
        join_into(&mut a, &[0, 2, 3]);
        assert_eq!(a, vec![1, 2, 3]);
        assert!(dominated(&[1, 2], &[1, 2, 3]));
        assert!(!dominated(&[2], &[1, 5]));
        assert!(dominated(&[], &[]));
    }

    #[test]
    fn counter_increments_never_lost() {
        let report = Explorer::new(2).schedules(64).run(|sess| {
            let c = AtomicU64::new(0);
            thread::scope(|s| {
                for tid in 0..2 {
                    let c = &c;
                    s.spawn(move || {
                        let _reg = sess.register(tid);
                        for _ in 0..3 {
                            c.fetch_add(1, Ordering::AcqRel);
                        }
                    });
                }
            });
            assert_eq!(c.load(Ordering::Acquire), 6);
        });
        report.assert_clean();
        assert_eq!(report.schedules_run, 64);
        assert!(report.distinct_schedules > 1, "schedules must differ");
    }

    #[test]
    fn release_acquire_publication_is_race_free() {
        let report = Explorer::new(2).schedules(128).run(|sess| {
            let data = Box::new(0u64);
            let addr = &*data as *const u64 as usize;
            let flag = AtomicBool::new(false);
            thread::scope(|s| {
                let flag = &flag;
                s.spawn(move || {
                    let _reg = sess.register(0);
                    trace_write(addr);
                    flag.store(true, Ordering::Release);
                });
                s.spawn(move || {
                    let _reg = sess.register(1);
                    while !flag.load(Ordering::Acquire) {}
                    trace_read(addr);
                });
            });
        });
        report.assert_clean();
    }

    #[test]
    fn relaxed_publication_is_a_detected_race() {
        let relaxed = Ordering::Relaxed;
        let report = Explorer::new(2).schedules(16).run(|sess| {
            let data = Box::new(0u64);
            let addr = &*data as *const u64 as usize;
            let flag = AtomicBool::new(false);
            thread::scope(|s| {
                let flag = &flag;
                s.spawn(move || {
                    let _reg = sess.register(0);
                    trace_write(addr);
                    flag.store(true, relaxed);
                });
                s.spawn(move || {
                    let _reg = sess.register(1);
                    while !flag.load(Ordering::Acquire) {}
                    trace_read(addr);
                });
            });
        });
        assert!(!report.failures.is_empty(), "relaxed publication must race");
        assert!(report.failures[0].contains("data race"), "{:?}", report.failures);
    }

    #[test]
    fn exhaustive_mode_enumerates_and_completes() {
        let report = Explorer::new(2).schedules(10_000).exhaustive().run(|sess| {
            let c = AtomicU64::new(0);
            thread::scope(|s| {
                for tid in 0..2 {
                    let c = &c;
                    s.spawn(move || {
                        let _reg = sess.register(tid);
                        c.fetch_add(1, Ordering::AcqRel);
                    });
                }
            });
            assert_eq!(c.load(Ordering::Acquire), 2);
        });
        report.assert_clean();
        assert!(report.complete, "tiny tree must be fully enumerated");
        assert!(report.distinct_schedules >= 2);
    }

    #[test]
    fn model_mutex_and_condvar_roundtrip() {
        let report = Explorer::new(2).schedules(64).run(|sess| {
            let slot: Mutex<Option<u32>> = Mutex::new(None);
            let ready = Condvar::new();
            thread::scope(|s| {
                let slot = &slot;
                let ready = &ready;
                s.spawn(move || {
                    let _reg = sess.register(0);
                    *slot.lock().unwrap() = Some(7);
                    ready.notify_all();
                });
                s.spawn(move || {
                    let _reg = sess.register(1);
                    let mut g = slot.lock().unwrap();
                    while g.is_none() {
                        g = ready.wait(g).unwrap();
                    }
                    assert_eq!(*g, Some(7));
                });
            });
        });
        report.assert_clean();
    }

    #[test]
    fn model_barrier_rendezvous() {
        let report = Explorer::new(2).schedules(48).run(|sess| {
            let b = Barrier::new(2);
            let data = Box::new(0u64);
            let addr = &*data as *const u64 as usize;
            thread::scope(|s| {
                let b = &b;
                s.spawn(move || {
                    let _reg = sess.register(0);
                    trace_write(addr);
                    b.wait();
                });
                s.spawn(move || {
                    let _reg = sess.register(1);
                    b.wait();
                    trace_read(addr);
                });
            });
        });
        report.assert_clean();
    }

    #[test]
    fn budget_exhaustion_is_reported_not_hung() {
        let report = Explorer::new(2).schedules(2).budget(500).run(|sess| {
            let flag = AtomicBool::new(false);
            thread::scope(|s| {
                let flag = &flag;
                s.spawn(move || {
                    let _reg = sess.register(0);
                    // Never set the flag: the sibling spins forever.
                    flag.load(Ordering::Acquire);
                });
                s.spawn(move || {
                    let _reg = sess.register(1);
                    while !flag.load(Ordering::Acquire) {}
                });
            });
        });
        assert!(!report.failures.is_empty());
        assert!(report.failures[0].contains("budget"), "{:?}", report.failures);
    }

    #[test]
    fn no_session_types_degrade_to_std_behavior() {
        let a = AtomicU64::new(1);
        assert_eq!(a.fetch_add(2, Ordering::SeqCst), 1);
        assert_eq!(a.load(Ordering::SeqCst), 3);
        let m = Mutex::new(5);
        *m.lock().unwrap() += 1;
        assert_eq!(m.into_inner().unwrap(), 6);
        let b = Barrier::new(2);
        let cv = Condvar::new();
        thread::scope(|s| {
            let b = &b;
            s.spawn(move || {
                b.wait();
            });
            b.wait();
        });
        let m2 = Mutex::new(false);
        let g = m2.lock().unwrap();
        let (g, to) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
        assert!(to.timed_out());
        assert!(!*g);
    }
}
