//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! Supports the idioms our invariant tests need: run a property over `N`
//! seeded random cases, report the failing seed/case on panic, and greedily
//! shrink integer-vector inputs. The RNG is [`crate::util::rng::Rng`], so
//! failures are reproducible by seed.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

impl Config {
    /// Convenience constructor.
    pub fn new(cases: usize, seed: u64) -> Self {
        Config { cases, seed }
    }
}

/// Run `prop` over `cfg.cases` random cases. `gen` builds a case from an RNG.
/// `prop` returns `Err(reason)` to signal a violation; we panic with the
/// reproducing seed.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        let case = gen(&mut rng);
        if let Err(reason) = prop(&case) {
            panic!(
                "property failed (case {i}, seed {case_seed:#x}): {reason}\ncase: {case:#?}"
            );
        }
    }
}

/// Like [`forall`] but also attempts greedy shrinking via `shrink`, which
/// should yield strictly "smaller" candidate cases.
pub fn forall_shrink<T: std::fmt::Debug + Clone>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        let case = gen(&mut rng);
        if let Err(first_reason) = prop(&case) {
            // Greedy shrink: walk to a locally-minimal failing case.
            let mut best = case.clone();
            let mut reason = first_reason;
            let mut budget = 200usize;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget = budget.saturating_sub(1);
                    if let Err(r) = prop(&cand) {
                        best = cand;
                        reason = r;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {i}, seed {case_seed:#x}): {reason}\nshrunk case: {best:#?}"
            );
        }
    }
}

/// Shrinker for integer vectors: drop halves, drop single elements, halve
/// element values.
pub fn shrink_vec_u64(v: &Vec<u64>) -> Vec<Vec<u64>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n <= 8 {
        for i in 0..n {
            let mut w = v.clone();
            w.remove(i);
            out.push(w);
        }
    }
    let halved: Vec<u64> = v.iter().map(|x| x / 2).collect();
    if &halved != v {
        out.push(halved);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            Config::new(10, 1),
            |r| r.next_below(100),
            |x| {
                count += 1;
                if *x < 100 {
                    Ok(())
                } else {
                    Err("impossible".into())
                }
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            Config::new(50, 2),
            |r| r.next_below(10),
            |x| if *x != 7 { Ok(()) } else { Err("hit 7".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "shrunk case")]
    fn shrinking_reduces_case() {
        forall_shrink(
            Config::new(20, 3),
            |r| (0..20).map(|_| r.next_below(1000)).collect::<Vec<u64>>(),
            shrink_vec_u64,
            |v| {
                if v.iter().any(|&x| x > 500) {
                    Err("contains big element".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrinker_produces_smaller_vectors() {
        let v: Vec<u64> = (0..10).collect();
        let shrunk = shrink_vec_u64(&v);
        assert!(!shrunk.is_empty());
        assert!(shrunk.iter().all(|s| s.len() <= v.len()));
    }
}
