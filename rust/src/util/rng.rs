//! Seedable pseudo-random number generation.
//!
//! The full `rand` crate is not available offline, so we implement
//! SplitMix64 (for seeding) and Xoshiro256** (for the main stream). Both are
//! public-domain algorithms (Blackman & Vigna) with well-understood
//! statistical quality — more than enough for graph generation and property
//! testing, and fully deterministic across platforms, which the reproduction
//! relies on (every experiment is seeded).

/// SplitMix64: tiny generator used to expand a 64-bit seed into state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministically seed the generator.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u64` in `[0, bound)` (Lemire's multiply-shift rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        // Simple unbiased rejection sampling on the high bits.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Log-normal draw with parameters `mu`, `sigma` of the underlying normal.
    pub fn next_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.next_gaussian()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.usize_below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.usize_below(slice.len())]
    }

    /// Derive an independent child RNG (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(42);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(11);
        let mut c1 = base.fork();
        let mut c2 = base.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
