//! Synchronization facade for the lock-free runtime.
//!
//! The concurrency kernel (`distributed::comm`, `distributed::barrier`,
//! `engine::superstep`, `serve::scheduler`) imports its sync primitives from
//! here instead of `std::sync`. In a normal build every name is a plain
//! re-export of the `std` type and [`trace_write`]/[`trace_read`] are empty
//! `#[inline(always)]` functions — the facade compiles away completely, so
//! the hot path is bit-for-bit the code it was before (the superstep
//! ablation bench pins this).
//!
//! Compiled with `RUSTFLAGS="--cfg unigps_model"`, the same names resolve to
//! the instrumented types in [`crate::util::model`]: every atomic access
//! becomes a scheduling point of a deterministic virtual scheduler, and the
//! trace hooks become vector-clock race checks. `rust/tests/model_check.rs`
//! runs the ported protocols under that cfg; see `docs/concurrency.md` for
//! how to run it locally.
//!
//! Outside a model session (i.e. for any code that happens to be compiled
//! under the cfg but is not running inside an
//! [`Explorer`](crate::util::model::Explorer) schedule) the instrumented
//! types fall back to plain `std` behavior, so the whole crate stays
//! correct under either cfg.
#![warn(missing_docs)]

/// Atomic types for the runtime's protocol state.
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    #[cfg(not(unigps_model))]
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};

    #[cfg(unigps_model)]
    pub use crate::util::model::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
}

#[cfg(not(unigps_model))]
pub use std::sync::{Barrier, BarrierWaitResult, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(unigps_model)]
pub use crate::util::model::{
    Barrier, BarrierWaitResult, Condvar, Mutex, MutexGuard, WaitTimeoutResult,
};

/// Cooperative cancellation handle: an atomic flag plus a reason string,
/// shared by cloning (clones observe the same cancellation). The scheduler
/// hands one token per job to the engine runtime via
/// [`RunOptions`](crate::engine::RunOptions); the superstep gates poll it
/// once per step, so a cancelled job unwinds to a typed
/// [`UniGpsError::Cancelled`](crate::error::UniGpsError::Cancelled) within
/// one superstep. Built on the facade's atomics so the cancel-vs-convergence
/// race is explorable under `--cfg unigps_model`.
#[derive(Clone, Default)]
pub struct CancelToken {
    inner: std::sync::Arc<CancelInner>,
}

struct CancelInner {
    cancelled: atomic::AtomicBool,
    reason: Mutex<Option<String>>,
}

// Manual impl: the model-checked atomics behind the facade do not derive
// `Default`, so the derive would not compile under `--cfg unigps_model`.
impl Default for CancelInner {
    fn default() -> CancelInner {
        CancelInner {
            cancelled: atomic::AtomicBool::new(false),
            reason: Mutex::new(None),
        }
    }
}

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation with a reason. The first reason wins; later
    /// calls are no-ops (the flag is already set and observers may have
    /// read the original reason).
    pub fn cancel(&self, reason: impl Into<String>) {
        let mut slot = self
            .inner
            .reason
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if slot.is_none() {
            *slot = Some(reason.into());
        }
        drop(slot);
        // Release-publish after the reason is written, so an Acquire
        // observer that sees the flag also sees a populated reason.
        self.inner.cancelled.store(true, atomic::Ordering::Release);
    }

    /// True once [`CancelToken::cancel`] has been called on any clone.
    pub fn is_cancelled(&self) -> bool {
        self.inner.cancelled.load(atomic::Ordering::Acquire)
    }

    /// The cancellation reason ("cancelled" if the flag is set but no
    /// reason was recorded; empty only before cancellation).
    pub fn reason(&self) -> String {
        self.inner
            .reason
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
            .unwrap_or_else(|| "cancelled".to_string())
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CancelToken")
            .field("cancelled", &self.is_cancelled())
            .finish()
    }
}

/// Declare a plain-memory write that the surrounding protocol orders (e.g.
/// a `FlatBoard` cell mutation protected by a seal epoch). Free in normal
/// builds; a race-checked scheduling point under `unigps_model`.
#[cfg(not(unigps_model))]
#[inline(always)]
pub fn trace_write(_addr: usize) {}

/// Declare a plain-memory read ordered by the surrounding protocol. Free in
/// normal builds; a race-checked scheduling point under `unigps_model`.
#[cfg(not(unigps_model))]
#[inline(always)]
pub fn trace_read(_addr: usize) {}

#[cfg(unigps_model)]
pub use crate::util::model::{trace_read, trace_write};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_flags_and_reason() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        let clone = t.clone();
        clone.cancel("deadline exceeded");
        assert!(t.is_cancelled(), "clones share one flag");
        assert_eq!(t.reason(), "deadline exceeded");
        // First reason wins.
        t.cancel("second");
        assert_eq!(clone.reason(), "deadline exceeded");
    }

    #[test]
    fn fresh_tokens_are_independent() {
        let a = CancelToken::new();
        let b = CancelToken::new();
        a.cancel("a only");
        assert!(!b.is_cancelled());
        assert!(format!("{a:?}").contains("true"));
    }
}
