//! Synchronization facade for the lock-free runtime.
//!
//! The concurrency kernel (`distributed::comm`, `distributed::barrier`,
//! `engine::superstep`, `serve::scheduler`) imports its sync primitives from
//! here instead of `std::sync`. In a normal build every name is a plain
//! re-export of the `std` type and [`trace_write`]/[`trace_read`] are empty
//! `#[inline(always)]` functions — the facade compiles away completely, so
//! the hot path is bit-for-bit the code it was before (the superstep
//! ablation bench pins this).
//!
//! Compiled with `RUSTFLAGS="--cfg unigps_model"`, the same names resolve to
//! the instrumented types in [`crate::util::model`]: every atomic access
//! becomes a scheduling point of a deterministic virtual scheduler, and the
//! trace hooks become vector-clock race checks. `rust/tests/model_check.rs`
//! runs the ported protocols under that cfg; see `docs/concurrency.md` for
//! how to run it locally.
//!
//! Outside a model session (i.e. for any code that happens to be compiled
//! under the cfg but is not running inside an
//! [`Explorer`](crate::util::model::Explorer) schedule) the instrumented
//! types fall back to plain `std` behavior, so the whole crate stays
//! correct under either cfg.
#![warn(missing_docs)]

/// Atomic types for the runtime's protocol state.
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    #[cfg(not(unigps_model))]
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};

    #[cfg(unigps_model)]
    pub use crate::util::model::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
}

#[cfg(not(unigps_model))]
pub use std::sync::{Barrier, BarrierWaitResult, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(unigps_model)]
pub use crate::util::model::{
    Barrier, BarrierWaitResult, Condvar, Mutex, MutexGuard, WaitTimeoutResult,
};

/// Declare a plain-memory write that the surrounding protocol orders (e.g.
/// a `FlatBoard` cell mutation protected by a seal epoch). Free in normal
/// builds; a race-checked scheduling point under `unigps_model`.
#[cfg(not(unigps_model))]
#[inline(always)]
pub fn trace_write(_addr: usize) {}

/// Declare a plain-memory read ordered by the surrounding protocol. Free in
/// normal builds; a race-checked scheduling point under `unigps_model`.
#[cfg(not(unigps_model))]
#[inline(always)]
pub fn trace_read(_addr: usize) {}

#[cfg(unigps_model)]
pub use crate::util::model::{trace_read, trace_write};
