//! Timing helpers used by engines, benches and EXPERIMENTS reporting.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed wall time.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed wall time in floating-point seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed wall time in milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Restart and return the lap duration.
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.start;
        self.start = now;
        d
    }
}

/// Accumulates named phase timings (per-superstep breakdowns etc.).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, Duration)>,
}

impl PhaseTimes {
    /// New empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name`.
    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += d;
        } else {
            self.entries.push((name.to_string(), d));
        }
    }

    /// Time a closure under phase `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    /// Total across phases.
    pub fn total(&self) -> Duration {
        self.entries.iter().map(|(_, d)| *d).sum()
    }

    /// Iterate `(name, duration)` pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.entries.iter().map(|(n, d)| (n.as_str(), *d))
    }

    /// Render as a compact single-line report.
    pub fn report(&self) -> String {
        let mut parts = Vec::new();
        for (n, d) in self.iter() {
            parts.push(format!("{n}={:.1}ms", d.as_secs_f64() * 1e3));
        }
        parts.join(" ")
    }
}

/// Per-thread CPU time (CLOCK_THREAD_CPUTIME_ID). Used for worker busy-time
/// accounting: on an oversubscribed machine (the 1-core testbed), wall time
/// counts preemption; CPU time counts actual work — which is what the
/// machine-scalability model (Fig 8c) needs.
///
/// Bound directly against the system C library (the `libc` crate is not
/// vendored in the offline build environment). The hand-rolled `timespec`
/// uses 64-bit fields, so the binding is gated to 64-bit Linux; other
/// platforms report zero, which degrades the Fig 8c model gracefully.
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn thread_cpu_time() -> Duration {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    extern "C" {
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
    let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain C call with a valid out-pointer; std already links libc.
    let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if rc != 0 {
        return Duration::ZERO;
    }
    Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
}

/// Fallback for platforms without the 64-bit Linux binding above.
#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn thread_cpu_time() -> Duration {
    Duration::ZERO
}

/// Stopwatch over the calling thread's CPU time.
#[derive(Debug, Clone)]
pub struct CpuTimer {
    start: Duration,
}

impl CpuTimer {
    /// Start measuring the current thread's CPU time.
    pub fn start() -> Self {
        CpuTimer { start: thread_cpu_time() }
    }

    /// CPU time consumed by this thread since `start`.
    pub fn elapsed(&self) -> Duration {
        thread_cpu_time().saturating_sub(self.start)
    }
}

/// The process-wide monotonic epoch every serving-path timestamp is
/// measured against. Lazily pinned on first use, so "microseconds since
/// epoch" values from any thread are mutually comparable and — unlike
/// `SystemTime` deltas — never go backwards under NTP steps. This is the
/// clock behind [`crate::obs`]'s span timestamps and uptime gauge.
static EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Microseconds elapsed since the process epoch (monotonic, comparable
/// across threads). The first caller pins the epoch.
pub fn monotonic_micros() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Throughput helper: items per second, guarding zero durations.
pub fn per_sec(items: u64, d: Duration) -> f64 {
    let s = d.as_secs_f64();
    if s <= 0.0 {
        f64::INFINITY
    } else {
        items as f64 / s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.secs() > 0.0);
        assert!(t.millis() >= 1.0);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = PhaseTimes::new();
        p.add("compute", Duration::from_millis(5));
        p.add("compute", Duration::from_millis(5));
        p.add("comm", Duration::from_millis(3));
        assert_eq!(p.total(), Duration::from_millis(13));
        let names: Vec<_> = p.iter().map(|(n, _)| n.to_string()).collect();
        assert_eq!(names, vec!["compute", "comm"]);
        assert!(p.report().contains("compute="));
    }

    #[test]
    fn monotonic_micros_never_regresses() {
        let a = monotonic_micros();
        std::thread::sleep(Duration::from_millis(1));
        let b = monotonic_micros();
        assert!(b > a, "monotonic clock must advance: {a} -> {b}");
        // Cross-thread comparability: a later read on another thread is
        // never behind an earlier read here.
        let c = std::thread::spawn(monotonic_micros).join().unwrap();
        assert!(c >= b);
    }

    #[test]
    fn throughput_math() {
        assert!((per_sec(1000, Duration::from_secs(2)) - 500.0).abs() < 1e-9);
        assert!(per_sec(10, Duration::from_secs(0)).is_infinite());
    }
}
