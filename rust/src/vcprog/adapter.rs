//! Wire codecs for VCProg data types.
//!
//! The IPC execution-isolation mechanism (§IV-C) ships vertex properties and
//! messages between the engine worker and the VCProg runner process using
//! the paper's row-based serialization. [`Wire`] is the codec trait: any
//! program whose `VProp`/`EProp`/`Msg` implement it can be served remotely
//! (see [`crate::ipc`]); the same bytes flow over the zero-copy shared-memory
//! channel and the socket RPC baseline.

use crate::error::{Result, UniGpsError};

/// Fixed, schema-less binary codec for VCProg value types.
pub trait Wire: Sized {
    /// Append the encoding of `self` to `out`.
    fn encode(&self, out: &mut Vec<u8>);
    /// Decode from `buf` starting at `pos`, advancing `pos`.
    fn decode(buf: &[u8], pos: &mut usize) -> Result<Self>;
}

fn take<'a>(buf: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *pos + n > buf.len() {
        return Err(UniGpsError::Ipc("truncated wire buffer".into()));
    }
    let s = &buf[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

macro_rules! impl_wire_num {
    ($($t:ty),*) => {$(
        impl Wire for $t {
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn decode(buf: &[u8], pos: &mut usize) -> Result<Self> {
                let n = std::mem::size_of::<$t>();
                let s = take(buf, pos, n)?;
                Ok(<$t>::from_le_bytes(s.try_into().unwrap()))
            }
        }
    )*};
}

impl_wire_num!(u32, u64, i32, i64, f32, f64);

impl Wire for () {
    fn encode(&self, _out: &mut Vec<u8>) {}
    fn decode(_buf: &[u8], _pos: &mut usize) -> Result<Self> {
        Ok(())
    }
}

impl Wire for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(*self as u8);
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Result<Self> {
        Ok(take(buf, pos, 1)?[0] != 0)
    }
}

impl<A: Wire, B: Wire> Wire for (A, B) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Result<Self> {
        Ok((A::decode(buf, pos)?, B::decode(buf, pos)?))
    }
}

impl<T: Wire> Wire for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        for x in self {
            x.encode(out);
        }
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let n = u32::decode(buf, pos)? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(T::decode(buf, pos)?);
        }
        Ok(v)
    }
}

/// Encode a value into a fresh buffer.
pub fn to_bytes<T: Wire>(v: &T) -> Vec<u8> {
    let mut out = Vec::new();
    v.encode(&mut out);
    out
}

/// Decode a value, requiring the whole buffer to be consumed.
pub fn from_bytes<T: Wire>(buf: &[u8]) -> Result<T> {
    let mut pos = 0;
    let v = T::decode(buf, &mut pos)?;
    if pos != buf.len() {
        return Err(UniGpsError::Ipc(format!(
            "trailing {} bytes after wire decode",
            buf.len() - pos
        )));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_roundtrip() {
        assert_eq!(from_bytes::<u32>(&to_bytes(&7u32)).unwrap(), 7);
        assert_eq!(from_bytes::<i64>(&to_bytes(&-9i64)).unwrap(), -9);
        assert_eq!(from_bytes::<f64>(&to_bytes(&2.5f64)).unwrap(), 2.5);
        assert!(from_bytes::<bool>(&to_bytes(&true)).unwrap());
    }

    #[test]
    fn tuple_and_vec_roundtrip() {
        let v: (f64, Vec<u32>) = (1.25, vec![3, 1, 4, 1, 5]);
        let bytes = to_bytes(&v);
        assert_eq!(from_bytes::<(f64, Vec<u32>)>(&bytes).unwrap(), v);
    }

    #[test]
    fn unit_is_zero_bytes() {
        assert!(to_bytes(&()).is_empty());
    }

    #[test]
    fn truncation_detected() {
        let bytes = to_bytes(&(1u64, 2u64));
        assert!(from_bytes::<(u64, u64)>(&bytes[..12]).is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut bytes = to_bytes(&3u32);
        bytes.push(0);
        assert!(from_bytes::<u32>(&bytes).is_err());
    }
}
