//! The **VCProg** unified vertex-centric programming model (paper §III).
//!
//! VCProg expresses graph processing as an iterative update of vertex
//! properties. Each iteration has three phases (paper Fig 1):
//!
//! 1. **merge messages** — each vertex folds its incoming messages with
//!    [`VCProg::merge_message`], starting from [`VCProg::empty_message`];
//! 2. **update vertex** — [`VCProg::vertex_compute`] produces the new
//!    property and the active flag;
//! 3. **send messages** — for every outgoing edge of an active vertex,
//!    [`VCProg::emit_message`] decides whether/what to send.
//!
//! A program runs until all vertices are inactive and no messages are in
//! flight, or `max_iter` rounds elapse (Algorithm 1). The same program object
//! is executed *unchanged* by every backend engine (Pregel, GAS, Push-Pull,
//! serial, tensor) — the paper's "Write Once, Run Anywhere" claim, which the
//! integration tests verify literally.
//!
//! ## Contract
//!
//! * `merge_message` must be **commutative**: `merge(a,b) == merge(b,a)`
//!   (the paper requires interchangeable message order), and associative.
//! * `empty_message` must be the **identity** of `merge_message`:
//!   `merge(m, empty) == m`.
//! * `emit_message` must be a pure function of `(src, dst, src_prop,
//!   edge_prop)` — engines may call it in any order, from any worker, any
//!   number of times.
//!
//! These laws are exactly what lets one program run under push (Pregel),
//! pull (GAS / Push-Pull dense) and hybrid schedules; the property tests in
//! `tests/` check them for every built-in program.

pub mod adapter;
pub mod programs;

use crate::error::{Result, UniGpsError};
use crate::graph::record::{FieldType, Value};
use std::fmt::Debug;

/// Vertex identifier (u32 — ample for the scaled datasets).
pub type VertexId = u32;

/// Iteration counter passed to `vertex_compute`. The first iteration is `1`
/// (matching Algorithm 1); every vertex is active in iteration 1 and
/// receives the empty message.
pub type Iteration = u32;

/// The unified vertex-centric program interface — the Rust rendering of the
/// paper's `VCProg` abstract base class (Fig 2).
///
/// Type parameters mirror the paper's data model: the vertex property
/// (`VProp`), edge property (`EProp`) and message (`Msg`) each have a single
/// schema shared by all instances. `In` is the *input* vertex property from
/// the loaded graph that [`VCProg::init_vertex_attr`] consumes.
pub trait VCProg: Send + Sync {
    /// Input vertex property type (from the loaded graph).
    type In: Clone + Send + Sync;
    /// Working/output vertex property type.
    type VProp: Clone + Send + Sync + Debug + PartialEq;
    /// Edge property type.
    type EProp: Clone + Send + Sync;
    /// Message type.
    type Msg: Clone + Send + Sync + Debug;

    /// Phase 0 (before iterations): produce the initial property of vertex
    /// `id` from its out-degree and input property.
    fn init_vertex_attr(&self, id: VertexId, out_degree: usize, input: &Self::In) -> Self::VProp;

    /// The global, read-only empty message: the identity of `merge_message`.
    fn empty_message(&self) -> Self::Msg;

    /// Phase 1: combine two messages. Must be commutative and associative
    /// with `empty_message` as identity.
    fn merge_message(&self, a: &Self::Msg, b: &Self::Msg) -> Self::Msg;

    /// Phase 2: compute the updated property of a vertex from its previous
    /// property, the merged message, and the iteration number (1-based).
    /// Returns `(new_prop, is_active)`.
    fn vertex_compute(
        &self,
        prop: &Self::VProp,
        msg: &Self::Msg,
        iter: Iteration,
    ) -> (Self::VProp, bool);

    /// Phase 3: decide whether to send a message along the edge
    /// `(src, dst)`. `None` means "do not emit" (the paper's
    /// `is_emit=False`).
    fn emit_message(
        &self,
        src: VertexId,
        dst: VertexId,
        src_prop: &Self::VProp,
        edge_prop: &Self::EProp,
    ) -> Option<Self::Msg>;

    /// Names and types of the per-vertex output columns this program
    /// produces (the paper: "vertex properties are output in tabular form").
    fn output_fields(&self) -> Vec<(&'static str, FieldType)>;

    /// Convert one final vertex property to its output row (same arity and
    /// order as [`VCProg::output_fields`]).
    fn output(&self, id: VertexId, prop: &Self::VProp) -> Vec<Value>;

    /// Human-readable program name (for logs/metrics).
    fn name(&self) -> &str {
        "vcprog"
    }

    /// Whether two messages merged with `merge_message` could ever differ
    /// from sending both separately — engines use this to enable sender-side
    /// combining (Giraph's Combiner). Default: combinable (true), which is
    /// sound given the algebraic laws above.
    fn combinable(&self) -> bool {
        true
    }

    /// Emit over all out-edges of `src` at once. Semantically identical to
    /// calling [`VCProg::emit_message`] per edge (the default does exactly
    /// that); proxied programs override this to collapse a vertex's whole
    /// scatter into **one** IPC round-trip — the paper's §VI "pipeline RPC
    /// invocations" future work, ablated in `benches/fig8d_ipc_optimization.rs`.
    fn emit_to_edges(
        &self,
        src: VertexId,
        src_prop: &Self::VProp,
        edges: &[(VertexId, &Self::EProp)],
    ) -> Vec<(VertexId, Self::Msg)> {
        edges
            .iter()
            .filter_map(|(dst, ep)| self.emit_message(src, *dst, src_prop, ep).map(|m| (*dst, m)))
            .collect()
    }

    /// True when the engine should prefer [`VCProg::emit_to_edges`] over
    /// per-edge emission (costs one small allocation per vertex, so only
    /// proxied programs opt in).
    fn prefers_batch_emit(&self) -> bool {
        false
    }
}

/// Output column data extracted from final vertex properties.
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    /// 64-bit integers.
    I64(Vec<i64>),
    /// 64-bit floats.
    F64(Vec<f64>),
}

impl Column {
    /// Column length.
    pub fn len(&self) -> usize {
        match self {
            Column::I64(v) => v.len(),
            Column::F64(v) => v.len(),
        }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// As i64 slice.
    pub fn as_i64(&self) -> Option<&[i64]> {
        match self {
            Column::I64(v) => Some(v),
            _ => None,
        }
    }

    /// As f64 slice.
    pub fn as_f64(&self) -> Option<&[f64]> {
        match self {
            Column::F64(v) => Some(v),
            _ => None,
        }
    }
}

/// Materialize a program's outputs over the final property vector into named
/// columns (used by every engine's result path).
///
/// A program whose `output` rows disagree with its `output_fields` schema
/// (wrong arity, wrong value type, unsupported field type) yields a typed
/// [`UniGpsError::Engine`] rather than aborting the process — user programs
/// (including remote/IPC-served ones) must not be able to panic the engine.
pub fn collect_columns<P: VCProg>(
    program: &P,
    props: &[P::VProp],
) -> Result<Vec<(String, Column)>> {
    let fields = program.output_fields();
    let mut cols: Vec<(String, Column)> = Vec::with_capacity(fields.len());
    for (n, t) in &fields {
        let col = match t {
            FieldType::Long => Column::I64(Vec::with_capacity(props.len())),
            FieldType::Double => Column::F64(Vec::with_capacity(props.len())),
            other => {
                return Err(UniGpsError::engine(format!(
                    "program '{}': unsupported output field type {other:?} for column '{n}' \
                     (tabular output supports Long and Double)",
                    program.name()
                )))
            }
        };
        cols.push((n.to_string(), col));
    }
    for (id, prop) in props.iter().enumerate() {
        let row = program.output(id as VertexId, prop);
        if row.len() != cols.len() {
            return Err(UniGpsError::engine(format!(
                "program '{}': output row for vertex {id} has {} values but \
                 output_fields declares {} columns",
                program.name(),
                row.len(),
                cols.len()
            )));
        }
        for (slot, value) in row.into_iter().enumerate() {
            match (&mut cols[slot].1, value) {
                (Column::I64(v), Value::Long(x)) => v.push(x),
                (Column::F64(v), Value::Double(x)) => v.push(x),
                (Column::F64(v), Value::Long(x)) => v.push(x as f64),
                (c, v) => {
                    return Err(UniGpsError::engine(format!(
                        "program '{}': output type mismatch at vertex {id}, column {slot}: \
                         expected {c:?}, got {v:?}",
                        program.name()
                    )))
                }
            }
        }
    }
    Ok(cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vcprog::programs::cc::ConnectedComponents;

    #[test]
    fn collect_columns_shapes() {
        let prog = ConnectedComponents::new();
        let props = vec![0u32, 0, 2];
        let cols = collect_columns(&prog, &props).unwrap();
        assert_eq!(cols.len(), 1);
        assert_eq!(cols[0].0, "component");
        assert_eq!(cols[0].1.as_i64().unwrap(), &[0, 0, 2]);
    }

    /// A deliberately misbehaving program for the error paths: declares one
    /// Long column but emits rows controlled by the vertex property.
    struct Misbehaving {
        fields: Vec<(&'static str, FieldType)>,
    }

    impl VCProg for Misbehaving {
        type In = ();
        type VProp = u8;
        type EProp = f64;
        type Msg = u32;

        fn init_vertex_attr(&self, _id: VertexId, _d: usize, _i: &()) -> u8 {
            0
        }
        fn empty_message(&self) -> u32 {
            0
        }
        fn merge_message(&self, a: &u32, b: &u32) -> u32 {
            a + b
        }
        fn vertex_compute(&self, p: &u8, _m: &u32, _i: Iteration) -> (u8, bool) {
            (*p, false)
        }
        fn emit_message(&self, _s: VertexId, _d: VertexId, _p: &u8, _e: &f64) -> Option<u32> {
            None
        }
        fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
            self.fields.clone()
        }
        fn output(&self, _id: VertexId, prop: &u8) -> Vec<Value> {
            match prop {
                0 => vec![Value::Long(1)],
                1 => vec![],                          // arity mismatch
                _ => vec![Value::Str("oops".into())], // type mismatch
            }
        }
        fn name(&self) -> &str {
            "misbehaving"
        }
    }

    #[test]
    fn collect_columns_rejects_bad_programs_without_panicking() {
        let long_field = Misbehaving {
            fields: vec![("x", FieldType::Long)],
        };
        // Well-formed rows pass.
        assert!(collect_columns(&long_field, &[0u8, 0]).is_ok());
        // Arity mismatch → typed engine error.
        let err = collect_columns(&long_field, &[0u8, 1]).unwrap_err();
        assert!(err.to_string().contains("output row"), "{err}");
        // Value/type mismatch → typed engine error.
        let err = collect_columns(&long_field, &[2u8]).unwrap_err();
        assert!(err.to_string().contains("type mismatch"), "{err}");
        // Unsupported declared field type → typed engine error.
        let bad_schema = Misbehaving {
            fields: vec![("x", FieldType::Str)],
        };
        let err = collect_columns(&bad_schema, &[0u8]).unwrap_err();
        assert!(err.to_string().contains("unsupported output field type"), "{err}");
    }

    #[test]
    fn column_accessors() {
        let c = Column::F64(vec![1.0, 2.0]);
        assert_eq!(c.len(), 2);
        assert!(!c.is_empty());
        assert!(c.as_i64().is_none());
        assert_eq!(c.as_f64().unwrap()[1], 2.0);
    }
}
