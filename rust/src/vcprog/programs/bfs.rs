//! Breadth-first search: hop distance from a root (unweighted SSSP).

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// Hop-infinity sentinel.
pub const UNREACHED: u32 = u32::MAX;

/// BFS program computing hop distances.
#[derive(Debug, Clone)]
pub struct Bfs {
    /// Root vertex.
    pub root: VertexId,
}

impl Bfs {
    /// BFS from `root`.
    pub fn new(root: VertexId) -> Self {
        Bfs { root }
    }
}

impl VCProg for Bfs {
    type In = ();
    type VProp = u32;
    type EProp = f64;
    type Msg = u32;

    fn init_vertex_attr(&self, id: VertexId, _out_degree: usize, _input: &()) -> u32 {
        if id == self.root {
            0
        } else {
            UNREACHED
        }
    }

    fn empty_message(&self) -> u32 {
        UNREACHED
    }

    fn merge_message(&self, a: &u32, b: &u32) -> u32 {
        *a.min(b)
    }

    fn vertex_compute(&self, prop: &u32, msg: &u32, iter: Iteration) -> (u32, bool) {
        if iter == 1 {
            return (*prop, *prop == 0);
        }
        if *msg < *prop {
            (*msg, true)
        } else {
            (*prop, false)
        }
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        src_prop: &u32,
        _edge_prop: &f64,
    ) -> Option<u32> {
        if *src_prop == UNREACHED {
            None
        } else {
            Some(src_prop + 1)
        }
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("hops", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &u32) -> Vec<Value> {
        vec![Value::Long(if *prop == UNREACHED { -1 } else { *prop as i64 })]
    }

    fn name(&self) -> &str {
        "bfs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laws_and_seed() {
        let p = Bfs::new(3);
        assert_eq!(p.merge_message(&2, &p.empty_message()), 2);
        assert_eq!(p.init_vertex_attr(3, 0, &()), 0);
        assert_eq!(p.init_vertex_attr(0, 0, &()), UNREACHED);
        let (_, active) = p.vertex_compute(&0, &UNREACHED, 1);
        assert!(active);
        let (_, active) = p.vertex_compute(&UNREACHED, &UNREACHED, 1);
        assert!(!active);
    }

    #[test]
    fn unreached_output_is_minus_one() {
        let p = Bfs::new(0);
        assert_eq!(p.output(1, &UNREACHED), vec![Value::Long(-1)]);
        assert_eq!(p.output(1, &4), vec![Value::Long(4)]);
    }
}
