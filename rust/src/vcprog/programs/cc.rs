//! Connected components via min-label propagation (the paper's CC workload).
//!
//! Every vertex starts labelled with its own id and repeatedly adopts the
//! minimum label heard from its in-neighbors. On a symmetrized (undirected)
//! graph this converges to weakly-connected components; the native `cc`
//! operator symmetrizes directed graphs first, matching NetworkX's
//! `connected_components` semantics the paper compares against.

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// Min-label-propagation connected components.
#[derive(Debug, Clone, Default)]
pub struct ConnectedComponents;

impl ConnectedComponents {
    /// New CC program.
    pub fn new() -> Self {
        ConnectedComponents
    }
}

/// Sentinel for "no message" (labels are vertex ids < u32::MAX).
const NO_LABEL: u32 = u32::MAX;

impl VCProg for ConnectedComponents {
    type In = ();
    type VProp = u32;
    type EProp = f64;
    type Msg = u32;

    fn init_vertex_attr(&self, id: VertexId, _out_degree: usize, _input: &()) -> u32 {
        id
    }

    fn empty_message(&self) -> u32 {
        NO_LABEL
    }

    fn merge_message(&self, a: &u32, b: &u32) -> u32 {
        *a.min(b)
    }

    fn vertex_compute(&self, prop: &u32, msg: &u32, iter: Iteration) -> (u32, bool) {
        if iter == 1 {
            // Everyone broadcasts its initial label.
            return (*prop, true);
        }
        if *msg < *prop {
            (*msg, true)
        } else {
            (*prop, false)
        }
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        src_prop: &u32,
        _edge_prop: &f64,
    ) -> Option<u32> {
        Some(*src_prop)
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("component", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &u32) -> Vec<Value> {
        vec![Value::Long(*prop as i64)]
    }

    fn name(&self) -> &str {
        "cc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_laws() {
        let p = ConnectedComponents::new();
        assert_eq!(p.merge_message(&3, &5), 3);
        assert_eq!(p.merge_message(&3, &p.empty_message()), 3);
        assert_eq!(p.merge_message(&7, &2), p.merge_message(&2, &7));
    }

    #[test]
    fn initial_label_is_id() {
        let p = ConnectedComponents::new();
        assert_eq!(p.init_vertex_attr(42, 0, &()), 42);
    }

    #[test]
    fn first_round_broadcasts() {
        let p = ConnectedComponents::new();
        let (label, active) = p.vertex_compute(&5, &NO_LABEL, 1);
        assert_eq!(label, 5);
        assert!(active);
    }

    #[test]
    fn adopts_smaller_label_only() {
        let p = ConnectedComponents::new();
        let (label, active) = p.vertex_compute(&5, &2, 3);
        assert_eq!(label, 2);
        assert!(active);
        let (label, active) = p.vertex_compute(&2, &5, 4);
        assert_eq!(label, 2);
        assert!(!active);
    }
}
