//! Degree counting: a two-round program computing in- and out-degrees.
//!
//! Round 1: every vertex records its out-degree (known at init) and sends a
//! `1` along every out-edge. Round 2: each vertex sums the received ones —
//! its in-degree. A minimal sanity workload exercising exactly one message
//! wave, handy for engine debugging and metrics tests.

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// Vertex state: out-degree (from init) and in-degree (from messages).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Degrees {
    /// Out-degree.
    pub out: u32,
    /// In-degree (filled in round 2).
    pub inn: u32,
}

/// Degree-count program.
#[derive(Debug, Clone, Default)]
pub struct DegreeCount;

impl DegreeCount {
    /// New degree counter.
    pub fn new() -> Self {
        DegreeCount
    }
}

impl VCProg for DegreeCount {
    type In = ();
    type VProp = Degrees;
    type EProp = f64;
    type Msg = u32;

    fn init_vertex_attr(&self, _id: VertexId, out_degree: usize, _input: &()) -> Degrees {
        Degrees {
            out: out_degree as u32,
            inn: 0,
        }
    }

    fn empty_message(&self) -> u32 {
        0
    }

    fn merge_message(&self, a: &u32, b: &u32) -> u32 {
        a + b
    }

    fn vertex_compute(&self, prop: &Degrees, msg: &u32, iter: Iteration) -> (Degrees, bool) {
        match iter {
            1 => (prop.clone(), true), // send the ones
            _ => (
                Degrees {
                    out: prop.out,
                    inn: prop.inn + *msg,
                },
                false,
            ),
        }
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        _src_prop: &Degrees,
        _edge_prop: &f64,
    ) -> Option<u32> {
        Some(1)
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("out_degree", FieldType::Long), ("in_degree", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &Degrees) -> Vec<Value> {
        vec![Value::Long(prop.out as i64), Value::Long(prop.inn as i64)]
    }

    fn name(&self) -> &str {
        "degree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_round_shape() {
        let p = DegreeCount::new();
        let s = p.init_vertex_attr(0, 4, &());
        assert_eq!(s.out, 4);
        let (s1, active) = p.vertex_compute(&s, &0, 1);
        assert!(active);
        let (s2, active) = p.vertex_compute(&s1, &7, 2);
        assert!(!active);
        assert_eq!(s2.inn, 7);
    }

    #[test]
    fn sum_merge() {
        let p = DegreeCount::new();
        assert_eq!(p.merge_message(&2, &3), 5);
        assert_eq!(p.merge_message(&2, &p.empty_message()), 2);
    }
}
