//! k-core decomposition membership: iterative peeling of vertices with
//! degree < k.
//!
//! A vertex that drops below degree `k` removes itself and notifies its
//! out-neighbors (message = number of removed in-neighbors, sum semiring);
//! survivors decrement their effective degree and may cascade. On a
//! symmetrized graph the survivors are exactly the k-core.

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// Vertex state for peeling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreState {
    /// Remaining effective degree.
    pub degree: i64,
    /// Whether the vertex has been peeled off.
    pub removed: bool,
}

/// k-core membership program.
#[derive(Debug, Clone)]
pub struct KCore {
    /// The core order `k`.
    pub k: i64,
}

impl KCore {
    /// k-core with the given `k`.
    pub fn new(k: i64) -> Self {
        KCore { k }
    }
}

impl VCProg for KCore {
    type In = ();
    type VProp = CoreState;
    type EProp = f64;
    type Msg = i64;

    fn init_vertex_attr(&self, _id: VertexId, out_degree: usize, _input: &()) -> CoreState {
        CoreState {
            degree: out_degree as i64,
            removed: false,
        }
    }

    fn empty_message(&self) -> i64 {
        0
    }

    fn merge_message(&self, a: &i64, b: &i64) -> i64 {
        a + b
    }

    fn vertex_compute(&self, prop: &CoreState, msg: &i64, _iter: Iteration) -> (CoreState, bool) {
        if prop.removed {
            // Already peeled; stay silent.
            return (prop.clone(), false);
        }
        let degree = prop.degree - msg;
        if degree < self.k {
            // Peel off now and notify neighbors (active → emit this round).
            (
                CoreState {
                    degree,
                    removed: true,
                },
                true,
            )
        } else {
            (
                CoreState {
                    degree,
                    removed: false,
                },
                false,
            )
        }
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        src_prop: &CoreState,
        _edge_prop: &f64,
    ) -> Option<i64> {
        // Only just-removed vertices are active, so this fires exactly once
        // per removed vertex.
        if src_prop.removed {
            Some(1)
        } else {
            None
        }
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("in_core", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &CoreState) -> Vec<Value> {
        vec![Value::Long(!prop.removed as i64)]
    }

    fn name(&self) -> &str {
        "kcore"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_degree_vertex_peels_immediately() {
        let p = KCore::new(2);
        let s = p.init_vertex_attr(0, 1, &());
        let (s2, active) = p.vertex_compute(&s, &0, 1);
        assert!(s2.removed);
        assert!(active);
        assert_eq!(p.emit_message(0, 1, &s2, &1.0), Some(1));
    }

    #[test]
    fn high_degree_vertex_survives_then_cascades() {
        let p = KCore::new(2);
        let s = p.init_vertex_attr(0, 2, &());
        let (s1, active) = p.vertex_compute(&s, &0, 1);
        assert!(!s1.removed);
        assert!(!active);
        // Loses one neighbor → degree 1 < 2 → peel.
        let (s2, active) = p.vertex_compute(&s1, &1, 2);
        assert!(s2.removed);
        assert!(active);
    }

    #[test]
    fn removed_vertices_stay_silent() {
        let p = KCore::new(2);
        let s = CoreState { degree: 0, removed: true };
        let (s2, active) = p.vertex_compute(&s, &3, 5);
        assert!(s2.removed);
        assert!(!active);
    }
}
