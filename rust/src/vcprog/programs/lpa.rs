//! Community detection by label propagation (LPA).
//!
//! Each vertex adopts the most frequent label among its in-neighbors, with
//! deterministic tie-breaking (smallest label wins). Messages carry a small
//! label histogram; the merge sums counts, which is commutative and
//! associative with the empty histogram as identity — demonstrating that
//! VCProg handles non-scalar message algebras.

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// A sparse label histogram, kept sorted by label.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Histogram {
    /// `(label, count)` pairs, ascending by label.
    pub counts: Vec<(u32, u32)>,
}

impl Histogram {
    /// Singleton histogram.
    pub fn single(label: u32) -> Self {
        Histogram {
            counts: vec![(label, 1)],
        }
    }

    /// Merge two histograms by summing counts (sorted merge).
    pub fn merge(&self, other: &Histogram) -> Histogram {
        let (a, b) = (&self.counts, &other.counts);
        let mut out = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].0.cmp(&b[j].0) {
                std::cmp::Ordering::Less => {
                    out.push(a[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(b[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.push((a[i].0, a[i].1 + b[j].1));
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
        Histogram { counts: out }
    }

    /// The winning label: max count, ties to the smallest label.
    pub fn argmax(&self) -> Option<u32> {
        self.counts
            .iter()
            .max_by(|(la, ca), (lb, cb)| ca.cmp(cb).then(lb.cmp(la)))
            .map(|(l, _)| *l)
    }
}

/// Label-propagation community detection.
#[derive(Debug, Clone)]
pub struct LabelPropagation {
    /// Number of propagation rounds.
    pub iterations: u32,
}

impl LabelPropagation {
    /// LPA with `iterations` propagation rounds.
    pub fn new(iterations: u32) -> Self {
        LabelPropagation { iterations }
    }

    /// Total VCProg rounds: 1 broadcast + `iterations` updates.
    pub fn rounds(&self) -> u32 {
        self.iterations + 1
    }
}

impl VCProg for LabelPropagation {
    type In = ();
    type VProp = u32;
    type EProp = f64;
    type Msg = Histogram;

    fn init_vertex_attr(&self, id: VertexId, _out_degree: usize, _input: &()) -> u32 {
        id
    }

    fn empty_message(&self) -> Histogram {
        Histogram::default()
    }

    fn merge_message(&self, a: &Histogram, b: &Histogram) -> Histogram {
        a.merge(b)
    }

    fn vertex_compute(&self, prop: &u32, msg: &Histogram, iter: Iteration) -> (u32, bool) {
        if iter == 1 {
            return (*prop, true);
        }
        let label = msg.argmax().unwrap_or(*prop);
        (label, iter < self.rounds())
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        src_prop: &u32,
        _edge_prop: &f64,
    ) -> Option<Histogram> {
        Some(Histogram::single(*src_prop))
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("community", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &u32) -> Vec<Value> {
        vec![Value::Long(*prop as i64)]
    }

    fn name(&self) -> &str {
        "lpa"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_merge_commutative() {
        let a = Histogram { counts: vec![(1, 2), (3, 1)] };
        let b = Histogram { counts: vec![(2, 5), (3, 4)] };
        assert_eq!(a.merge(&b), b.merge(&a));
        assert_eq!(
            a.merge(&b).counts,
            vec![(1, 2), (2, 5), (3, 5)]
        );
    }

    #[test]
    fn empty_is_identity() {
        let a = Histogram { counts: vec![(7, 3)] };
        assert_eq!(a.merge(&Histogram::default()), a);
    }

    #[test]
    fn argmax_breaks_ties_low() {
        let h = Histogram { counts: vec![(2, 3), (5, 3), (9, 1)] };
        assert_eq!(h.argmax(), Some(2));
        assert_eq!(Histogram::default().argmax(), None);
    }

    #[test]
    fn keeps_label_without_messages() {
        let p = LabelPropagation::new(2);
        let (l, _) = p.vertex_compute(&4, &Histogram::default(), 2);
        assert_eq!(l, 4);
    }

    #[test]
    fn stops_after_rounds() {
        let p = LabelPropagation::new(2);
        let (_, active) = p.vertex_compute(&0, &Histogram::single(1), 2);
        assert!(active);
        let (_, active) = p.vertex_compute(&0, &Histogram::single(1), 3);
        assert!(!active);
    }
}
