//! Built-in VCProg programs.
//!
//! Every algorithm here is written **against the VCProg API only** — no
//! engine internals — which is what the paper's "Write Once, Run Anywhere"
//! property requires. The native-operator layer ([`crate::operators`]) wraps
//! these with friendlier entry points, mirroring the paper's split between
//! the VCProg API and the native operator API (Fig 3 bottom).

pub mod bfs;
pub mod cc;
pub mod degree;
pub mod kcore;
pub mod lpa;
pub mod pagerank;
pub mod reachability;
pub mod sssp;
pub mod triangle;

pub use bfs::Bfs;
pub use cc::ConnectedComponents;
pub use degree::DegreeCount;
pub use kcore::KCore;
pub use lpa::LabelPropagation;
pub use pagerank::PageRank;
pub use reachability::Reachability;
pub use sssp::SsspBellmanFord;
pub use triangle::TriangleCount;
