//! PageRank as a VCProg program.
//!
//! Standard message-passing PageRank (the paper's PR workload): each active
//! vertex sends `rank / out_degree` along its out-edges; each vertex updates
//! `rank = (1-d)/N + d * Σ incoming`. Runs for a fixed number of iterations
//! so results are engine-order independent up to floating-point summation
//! order (the cross-engine tests compare with a small tolerance).

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// Per-vertex PageRank state.
#[derive(Debug, Clone, PartialEq)]
pub struct PrState {
    /// Current rank.
    pub rank: f64,
    /// Cached out-degree (used by emit).
    pub out_degree: u32,
}

/// PageRank program.
#[derive(Debug, Clone)]
pub struct PageRank {
    /// Total number of vertices `N`.
    pub num_vertices: usize,
    /// Damping factor (paper-typical 0.85).
    pub damping: f64,
    /// Number of rank-update iterations to run.
    pub iterations: u32,
}

impl PageRank {
    /// PageRank with `iterations` updates over an `n`-vertex graph.
    pub fn new(num_vertices: usize, iterations: u32) -> Self {
        PageRank {
            num_vertices,
            damping: 0.85,
            iterations,
        }
    }

    /// Total VCProg rounds needed: one send-only round plus `iterations`
    /// update rounds (engines should set `max_iter >= rounds()`).
    pub fn rounds(&self) -> u32 {
        self.iterations + 1
    }
}

impl VCProg for PageRank {
    type In = ();
    type VProp = PrState;
    type EProp = f64;
    type Msg = f64;

    fn init_vertex_attr(&self, _id: VertexId, out_degree: usize, _input: &()) -> PrState {
        PrState {
            rank: 1.0 / self.num_vertices as f64,
            out_degree: out_degree as u32,
        }
    }

    fn empty_message(&self) -> f64 {
        0.0
    }

    fn merge_message(&self, a: &f64, b: &f64) -> f64 {
        a + b
    }

    fn vertex_compute(&self, prop: &PrState, msg: &f64, iter: Iteration) -> (PrState, bool) {
        if iter == 1 {
            // Round 1 only seeds the first messages; ranks stay 1/N.
            return (prop.clone(), iter < self.rounds());
        }
        let rank = (1.0 - self.damping) / self.num_vertices as f64 + self.damping * msg;
        (
            PrState {
                rank,
                out_degree: prop.out_degree,
            },
            iter < self.rounds(),
        )
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        src_prop: &PrState,
        _edge_prop: &f64,
    ) -> Option<f64> {
        if src_prop.out_degree == 0 {
            None
        } else {
            Some(src_prop.rank / src_prop.out_degree as f64)
        }
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("rank", FieldType::Double)]
    }

    fn output(&self, _id: VertexId, prop: &PrState) -> Vec<Value> {
        vec![Value::Double(prop.rank)]
    }

    fn name(&self) -> &str {
        "pagerank"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_laws() {
        let pr = PageRank::new(10, 5);
        let e = pr.empty_message();
        assert_eq!(pr.merge_message(&2.5, &e), 2.5);
        assert_eq!(pr.merge_message(&1.0, &2.0), pr.merge_message(&2.0, &1.0));
    }

    #[test]
    fn init_uniform() {
        let pr = PageRank::new(4, 3);
        let s = pr.init_vertex_attr(0, 7, &());
        assert_eq!(s.rank, 0.25);
        assert_eq!(s.out_degree, 7);
    }

    #[test]
    fn dangling_vertex_emits_nothing() {
        let pr = PageRank::new(4, 3);
        let s = PrState { rank: 0.25, out_degree: 0 };
        assert!(pr.emit_message(0, 1, &s, &1.0).is_none());
    }

    #[test]
    fn compute_applies_damping() {
        let pr = PageRank::new(10, 3);
        let s = PrState { rank: 0.1, out_degree: 2 };
        let (s2, active) = pr.vertex_compute(&s, &0.2, 2);
        let expect = 0.15 / 10.0 + 0.85 * 0.2;
        assert!((s2.rank - expect).abs() < 1e-12);
        assert!(active);
        // Final round: inactive afterwards.
        let (_, active) = pr.vertex_compute(&s, &0.2, pr.rounds());
        assert!(!active);
    }

    #[test]
    fn first_round_preserves_rank() {
        let pr = PageRank::new(10, 3);
        let s = pr.init_vertex_attr(0, 1, &());
        let (s2, active) = pr.vertex_compute(&s, &0.0, 1);
        assert_eq!(s2.rank, s.rank);
        assert!(active);
    }
}
