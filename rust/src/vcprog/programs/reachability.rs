//! Reachability from a root: boolean frontier propagation.
//!
//! The minimal "is there a path root→v" program — a BFS without distances,
//! useful as the simplest possible VCProg example in the docs.

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// Reachability program.
#[derive(Debug, Clone)]
pub struct Reachability {
    /// Root vertex.
    pub root: VertexId,
}

impl Reachability {
    /// Reachability from `root`.
    pub fn new(root: VertexId) -> Self {
        Reachability { root }
    }
}

impl VCProg for Reachability {
    type In = ();
    type VProp = bool;
    type EProp = f64;
    type Msg = bool;

    fn init_vertex_attr(&self, id: VertexId, _out_degree: usize, _input: &()) -> bool {
        id == self.root
    }

    fn empty_message(&self) -> bool {
        false
    }

    fn merge_message(&self, a: &bool, b: &bool) -> bool {
        *a || *b
    }

    fn vertex_compute(&self, prop: &bool, msg: &bool, iter: Iteration) -> (bool, bool) {
        if iter == 1 {
            return (*prop, *prop); // root starts the wave
        }
        if *msg && !*prop {
            (true, true) // newly reached → propagate
        } else {
            (*prop, false)
        }
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        src_prop: &bool,
        _edge_prop: &f64,
    ) -> Option<bool> {
        if *src_prop {
            Some(true)
        } else {
            None
        }
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("reachable", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &bool) -> Vec<Value> {
        vec![Value::Long(*prop as i64)]
    }

    fn name(&self) -> &str {
        "reachability"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn or_algebra() {
        let p = Reachability::new(0);
        assert!(p.merge_message(&true, &false));
        assert!(!p.merge_message(&false, &p.empty_message()));
    }

    #[test]
    fn wave_semantics() {
        let p = Reachability::new(0);
        // Root active in round 1.
        assert_eq!(p.vertex_compute(&true, &false, 1), (true, true));
        // Non-root idle in round 1.
        assert_eq!(p.vertex_compute(&false, &false, 1), (false, false));
        // Newly reached propagates once.
        assert_eq!(p.vertex_compute(&false, &true, 2), (true, true));
        // Already reached stays silent.
        assert_eq!(p.vertex_compute(&true, &true, 3), (true, false));
    }
}
