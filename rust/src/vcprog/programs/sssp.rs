//! Single-source shortest path (Bellman-Ford) — the paper's running example
//! (Fig 3, `UniSSSP`).
//!
//! Distances are kept as `i64` (edge weights are rounded to integers) so the
//! min-plus semiring is exact and every engine returns bit-identical
//! results. `i64::MAX` plays the paper's `sys.maxsize` infinity.

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

/// Infinity distance (paper: `sys.maxsize`).
pub const INF: i64 = i64::MAX;

/// Bellman-Ford SSSP program.
#[derive(Debug, Clone)]
pub struct SsspBellmanFord {
    /// Source vertex (paper: `self.ROOT`).
    pub root: VertexId,
}

impl SsspBellmanFord {
    /// SSSP from `root`.
    pub fn new(root: VertexId) -> Self {
        SsspBellmanFord { root }
    }
}

impl VCProg for SsspBellmanFord {
    type In = ();
    type VProp = i64;
    type EProp = f64;
    type Msg = i64;

    fn init_vertex_attr(&self, id: VertexId, _out_degree: usize, _input: &()) -> i64 {
        if id == self.root {
            0
        } else {
            INF
        }
    }

    fn empty_message(&self) -> i64 {
        INF
    }

    fn merge_message(&self, a: &i64, b: &i64) -> i64 {
        *a.min(b)
    }

    fn vertex_compute(&self, prop: &i64, msg: &i64, iter: Iteration) -> (i64, bool) {
        let mut dist = *prop;
        let mut active = false;
        if *msg < dist {
            dist = *msg;
            active = true;
        }
        // Paper Fig 3: in the first iteration only the root activates (to
        // seed the propagation).
        if iter == 1 && dist == 0 && self.rooted(prop) {
            active = true;
        }
        (dist, active)
    }

    fn emit_message(
        &self,
        _src: VertexId,
        _dst: VertexId,
        src_prop: &i64,
        edge_prop: &f64,
    ) -> Option<i64> {
        if *src_prop == INF {
            None
        } else {
            Some(src_prop.saturating_add(edge_prop.round() as i64))
        }
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("distance", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &i64) -> Vec<Value> {
        vec![Value::Long(*prop)]
    }

    fn name(&self) -> &str {
        "sssp"
    }
}

impl SsspBellmanFord {
    /// True when this property can only belong to the root in iteration 1
    /// (distance 0 before any message arrived).
    fn rooted(&self, prop: &i64) -> bool {
        *prop == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_is_min_with_inf_identity() {
        let p = SsspBellmanFord::new(0);
        assert_eq!(p.merge_message(&5, &3), 3);
        assert_eq!(p.merge_message(&5, &INF), 5);
        assert_eq!(p.merge_message(&INF, &INF), INF);
    }

    #[test]
    fn init_marks_root() {
        let p = SsspBellmanFord::new(2);
        assert_eq!(p.init_vertex_attr(2, 3, &()), 0);
        assert_eq!(p.init_vertex_attr(0, 3, &()), INF);
    }

    #[test]
    fn root_active_in_round_one() {
        let p = SsspBellmanFord::new(0);
        let (d, active) = p.vertex_compute(&0, &INF, 1);
        assert_eq!(d, 0);
        assert!(active);
        let (d, active) = p.vertex_compute(&INF, &INF, 1);
        assert_eq!(d, INF);
        assert!(!active);
    }

    #[test]
    fn improvement_activates() {
        let p = SsspBellmanFord::new(0);
        let (d, active) = p.vertex_compute(&10, &7, 3);
        assert_eq!(d, 7);
        assert!(active);
        let (d, active) = p.vertex_compute(&7, &9, 4);
        assert_eq!(d, 7);
        assert!(!active);
    }

    #[test]
    fn unreached_vertices_emit_nothing() {
        let p = SsspBellmanFord::new(0);
        assert!(p.emit_message(1, 2, &INF, &4.0).is_none());
        assert_eq!(p.emit_message(0, 1, &3, &4.0), Some(7));
    }

    #[test]
    fn saturating_add_guards_overflow() {
        let p = SsspBellmanFord::new(0);
        assert_eq!(p.emit_message(0, 1, &(INF - 1), &4.0), Some(INF));
    }
}
