//! Triangle counting as a VCProg program.
//!
//! Three rounds on a symmetrized **simple** graph:
//!
//! 1. every vertex broadcasts `(own id, ∅)`;
//! 2. each vertex learns its in-neighbor set (the senders of round-1
//!    messages), stores it, and broadcasts `(own id, neighbor set)`;
//! 3. each vertex intersects **every received set individually** with its
//!    own neighbor set. A triangle through `v` is found twice (once via each
//!    of its other two corners), so `triangles(v) = hits/2` and the global
//!    count is `Σ hits / 6`.
//!
//! Messages are sender-tagged sets merged by sender id — a commutative,
//! associative multiset union with `∅` as identity. Per-sender tagging is
//! essential: merging the sets themselves would collapse common neighbors
//! shared by several senders and under-count (caught by the oracle tests).
//! This program exercises variable-size message payloads through every
//! engine and the IPC serialization path.

use crate::graph::record::{FieldType, Value};
use crate::vcprog::{Iteration, VCProg, VertexId};

fn intersect_count(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j, mut c) = (0, 0, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// Message: sender-tagged neighbor sets, ascending by sender.
pub type TriMsg = Vec<(u32, Vec<u32>)>;

/// Vertex state across the three rounds.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TriState {
    /// Sorted in-neighbor set (learned in round 2).
    pub neighbors: Vec<u32>,
    /// Set once neighbors have been learned (distinguishes rounds in emit).
    pub learned: bool,
    /// 2 × number of triangles through this vertex (from round 3).
    pub hits: u64,
}

/// Triangle-count program (expects a symmetrized simple graph).
#[derive(Debug, Clone, Default)]
pub struct TriangleCount;

impl TriangleCount {
    /// New triangle counter.
    pub fn new() -> Self {
        TriangleCount
    }

    /// Global triangle count from the per-vertex `hits` output column.
    pub fn global_from_hits(hits: &[i64]) -> u64 {
        let total: i64 = hits.iter().sum();
        (total / 6) as u64
    }
}

impl VCProg for TriangleCount {
    type In = ();
    type VProp = TriState;
    type EProp = f64;
    type Msg = TriMsg;

    fn init_vertex_attr(&self, _id: VertexId, _out_degree: usize, _input: &()) -> TriState {
        TriState::default()
    }

    fn empty_message(&self) -> TriMsg {
        Vec::new()
    }

    fn merge_message(&self, a: &TriMsg, b: &TriMsg) -> TriMsg {
        // Sorted merge by sender id. On a simple graph each sender appears at
        // most once per round, so equal keys only arise from merging with
        // self-duplicates; keep both sides' payload union in that case.
        let mut out = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].0.cmp(&b[j].0) {
                std::cmp::Ordering::Less => {
                    out.push(a[i].clone());
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(b[j].clone());
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.push(a[i].clone());
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
        out
    }

    fn vertex_compute(&self, prop: &TriState, msg: &TriMsg, iter: Iteration) -> (TriState, bool) {
        match iter {
            1 => (prop.clone(), true), // broadcast own id
            2 => {
                // Senders of round-1 messages are exactly the in-neighbors.
                let neighbors: Vec<u32> = msg.iter().map(|(s, _)| *s).collect();
                (
                    TriState {
                        neighbors,
                        learned: true,
                        hits: 0,
                    },
                    true, // broadcast neighbor set
                )
            }
            3 => {
                let hits: u64 = msg
                    .iter()
                    .map(|(_, set)| intersect_count(&prop.neighbors, set))
                    .sum();
                (
                    TriState {
                        neighbors: prop.neighbors.clone(),
                        learned: true,
                        hits,
                    },
                    false,
                )
            }
            _ => (prop.clone(), false),
        }
    }

    fn emit_message(
        &self,
        src: VertexId,
        _dst: VertexId,
        src_prop: &TriState,
        _edge_prop: &f64,
    ) -> Option<TriMsg> {
        if !src_prop.learned {
            // Round 1: announce own id.
            Some(vec![(src, Vec::new())])
        } else {
            // Round 2: send the neighbor set, tagged by sender.
            Some(vec![(src, src_prop.neighbors.clone())])
        }
    }

    fn output_fields(&self) -> Vec<(&'static str, FieldType)> {
        vec![("hits", FieldType::Long)]
    }

    fn output(&self, _id: VertexId, prop: &TriState) -> Vec<Value> {
        vec![Value::Long(prop.hits as i64)]
    }

    fn name(&self) -> &str {
        "triangle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_laws() {
        let t = TriangleCount::new();
        let a: TriMsg = vec![(1, vec![2, 3]), (5, vec![1])];
        let b: TriMsg = vec![(2, vec![9]), (7, vec![])];
        assert_eq!(t.merge_message(&a, &b), t.merge_message(&b, &a));
        assert_eq!(t.merge_message(&a, &t.empty_message()), a);
        let merged = t.merge_message(&a, &b);
        let senders: Vec<u32> = merged.iter().map(|(s, _)| *s).collect();
        assert_eq!(senders, vec![1, 2, 5, 7]);
    }

    #[test]
    fn intersect_counts() {
        assert_eq!(intersect_count(&[1, 2, 3], &[2, 3, 4]), 2);
        assert_eq!(intersect_count(&[], &[1]), 0);
    }

    #[test]
    fn round_progression_single_triangle() {
        // Triangle 0-1-2 seen from vertex 0.
        let t = TriangleCount::new();
        let s0 = t.init_vertex_attr(0, 2, &());
        let (s1, a1) = t.vertex_compute(&s0, &vec![], 1);
        assert!(a1);
        // Round 2: messages from in-neighbors 1 and 2.
        let msg2: TriMsg = vec![(1, vec![]), (2, vec![])];
        let (s2, a2) = t.vertex_compute(&s1, &msg2, 2);
        assert!(a2);
        assert_eq!(s2.neighbors, vec![1, 2]);
        // Round 3: neighbor sets of 1 and 2.
        let msg3: TriMsg = vec![(1, vec![0, 2]), (2, vec![0, 1])];
        let (s3, a3) = t.vertex_compute(&s2, &msg3, 3);
        assert!(!a3);
        assert_eq!(s3.hits, 2, "one triangle → 2 hits per corner");
    }

    #[test]
    fn shared_edge_triangles_counted_per_sender() {
        // Triangles (0,1,2) and (0,1,3) share edge 0-1; from vertex 0:
        // neighbors {1,2,3}; sets: N(1)={0,2,3}, N(2)={0,1}, N(3)={0,1}.
        let t = TriangleCount::new();
        let s = TriState {
            neighbors: vec![1, 2, 3],
            learned: true,
            hits: 0,
        };
        let msg: TriMsg = vec![(1, vec![0, 2, 3]), (2, vec![0, 1]), (3, vec![0, 1])];
        let (s3, _) = t.vertex_compute(&s, &msg, 3);
        assert_eq!(s3.hits, 4, "two triangles → 4 hits at vertex 0");
    }
}
