//! Chaos harness: the serve stack under deterministic fault injection
//! (`util::fault`). A pinned-seed spec arms failpoints across the
//! snapshot cache, the scheduler slots and both transports, then a burst
//! of jobs — some cancelled mid-flight — is driven through a live
//! server. The invariants under fire:
//!
//! * every admitted job reaches a terminal state (`Done | Failed |
//!   Cancelled`) — a fault may fail a job, never wedge it;
//! * the scheduler's books balance: `completed + failed + cancelled ==
//!   submitted`, nothing left queued or running — and the same balance
//!   holds in the process-global `obs::metrics` counters (asserted as
//!   deltas across the run), with the queue/running gauges reading
//!   empty and reconnect counts bounding idempotent replays;
//! * shutdown still drains cleanly and the process returns to its
//!   baseline thread count — no leaked handler, runner or watchdog
//!   threads.
//!
//! A separate leg fires the `ingest-apply` failpoint under delta
//! ingestion: failed applies leave the dataset's generation chain and
//! the cache's invalidation books untouched, retrying the same batch
//! succeeds, and committed epochs stay dense and monotone.
//!
//! CI runs this binary as a blocking leg with `UNIGPS_FAULTS` exported
//! at a fixed seed; locally the same pinned spec is activated
//! programmatically, so the run replays identically either way. The
//! transport matrix is the same `UNIGPS_TEST_TRANSPORT=uds|tcp` switch
//! as `serve_integration.rs`.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};
use unigps::client::Client;
use unigps::delta::DeltaBatch;
use unigps::error::UniGpsError;
use unigps::ipc::shm::ShmMap;
use unigps::plan::DatasetRef;
use unigps::serve::{JobId, RemoteClient, ServeClient, ServeConfig, Server};
use unigps::session::Session;
use unigps::util::fault;

/// The pinned chaos spec CI exports as `UNIGPS_FAULTS`; the environment
/// wins when set so the leg can pin a different seed without a rebuild.
const PINNED_SPEC: &str = "seed=42;cache-load=error@0.25;sched-run=error@0.25;\
                           transport-read=drop@0.03;transport-write=drop@0.03;\
                           transport-connect=error@0.05;result-stream=drop@0.15";

fn chaos_spec() -> String {
    std::env::var("UNIGPS_FAULTS")
        .ok()
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| PINNED_SPEC.to_string())
}

/// The fault registry is process-global: tests serialize on this lock so
/// one test's spec never bleeds into another's run.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    CHAOS_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

const TEST_TOKEN: &str = "chaos-token";

fn test_transport() -> String {
    std::env::var("UNIGPS_TEST_TRANSPORT").unwrap_or_else(|_| "uds".into())
}

struct TestServe {
    socket: PathBuf,
    tcp_addr: Option<std::net::SocketAddr>,
    handle: std::thread::JoinHandle<()>,
}

impl TestServe {
    /// A fresh client, retrying while `transport-connect` faults fire —
    /// connecting is idempotent, so a bounded retry is always safe.
    fn client(&self) -> Box<dyn Client> {
        let mut last: Option<UniGpsError> = None;
        for _ in 0..10 {
            let attempt: Result<Box<dyn Client>, UniGpsError> = match self.tcp_addr {
                Some(addr) => RemoteClient::connect_tcp(&addr.to_string(), TEST_TOKEN)
                    .map(|c| Box::new(c) as Box<dyn Client>),
                None => ServeClient::connect(&self.socket).map(|c| Box::new(c) as Box<dyn Client>),
            };
            match attempt {
                Ok(c) => return c,
                Err(e) => last = Some(e),
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        panic!("could not connect through injected faults: {last:?}");
    }

    fn join(self) {
        self.handle.join().expect("server thread");
    }
}

fn start_server() -> TestServe {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("chaos"));
    cfg.slots = 2;
    cfg.queue_cap = 64;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 4;
    if test_transport() == "tcp" {
        cfg.tcp = Some("127.0.0.1:0".into());
        cfg.token = Some(TEST_TOKEN.into());
    }
    let socket = cfg.socket.clone();
    let server = Server::bind(Session::builder().build(), cfg).expect("bind serve listeners");
    let tcp_addr = server.tcp_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve loop"));
    TestServe {
        socket,
        tcp_addr,
        handle,
    }
}

/// This process's live thread count (`/proc/self/status`), or `None`
/// off-Linux — the leak assertion is skipped there.
fn thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|n| n.parse().ok())
}

/// Submit with a bounded retry across fresh connections: a transport
/// fault can kill the submit round trip, and a lost *response* means the
/// job may be admitted server-side anyway — callers reconcile through
/// the scheduler's own books, never by resubmission accounting.
fn submit_chaotic(server: &TestServe, spec: &str) -> Option<JobId> {
    for _ in 0..8 {
        let mut client = server.client();
        match client.submit(spec) {
            Ok(id) => return Some(id),
            // Transport-level failure: ambiguous, try a fresh connection.
            Err(UniGpsError::Io(_) | UniGpsError::Ipc(_)) => {}
            // A typed server answer (bad spec, backpressure) is a real
            // admission verdict, not chaos noise.
            Err(e) => panic!("unexpected typed submit rejection: {e:?}"),
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    None
}

/// Poll a job to a terminal state through whatever connections survive.
fn wait_terminal_chaotic(server: &TestServe, id: JobId, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    let mut client = server.client();
    loop {
        match client.status(id) {
            Ok(st) if st.state.is_terminal() => return,
            Ok(_) => std::thread::sleep(Duration::from_millis(25)),
            Err(UniGpsError::Io(_) | UniGpsError::Ipc(_)) => {
                client = server.client();
            }
            Err(e) => panic!("job {id}: typed status error under chaos: {e:?}"),
        }
        assert!(
            Instant::now() < deadline,
            "job {id} not terminal within {timeout:?} under injected faults"
        );
    }
}

/// The blocking CI leg: a job burst against a fully-armed failpoint
/// registry, with the terminal/books/drain/thread-leak invariants
/// asserted at the end.
#[test]
fn every_job_ends_terminal_and_the_server_drains_under_faults() {
    let _g = locked();
    fault::clear();
    let baseline_threads = thread_count();
    // The obs registry is process-global and the sibling test feeds it
    // too, so its books-balance invariant is asserted on deltas across
    // this run (CHAOS_LOCK serializes the two tests).
    let obs_before = unigps::obs::metrics::snapshot();

    // Bind and start clean — chaos begins once the listeners are up.
    let server = start_server();
    fault::activate(&chaos_spec()).expect("chaos spec parses");

    let quick = "kind = rmat\nvertices = 256\nedges = 1024\nseed = 11\nworkers = 2\nalgo = sssp";
    let slow = format!("{quick}\ndelay_ms = 300");
    let jobs: usize = 24;
    let mut known: Vec<JobId> = Vec::new();
    let mut cancelled_targets: Vec<JobId> = Vec::new();
    for j in 0..jobs {
        let spec = if j % 4 == 0 { slow.as_str() } else { quick };
        let Some(id) = submit_chaotic(&server, spec) else {
            // Every connection attempt lost to injected drops — rare at
            // the pinned seed, and the books below still must balance.
            continue;
        };
        known.push(id);
        // Mix cancellation into the chaos: every slow job is cancelled
        // mid-flight (terminal-state cancels are no-ops, so racing the
        // job's natural completion is fine).
        if j % 4 == 0 {
            let mut client = server.client();
            match client.cancel(id) {
                Ok(_) => cancelled_targets.push(id),
                Err(UniGpsError::Io(_) | UniGpsError::Ipc(_)) => {}
                Err(e) => panic!("typed cancel error under chaos: {e:?}"),
            }
        }
    }
    assert!(
        known.len() >= jobs / 2,
        "chaos drowned admission: only {} of {jobs} submits landed",
        known.len()
    );

    // Invariant 1: every known-admitted job goes terminal under fire.
    for &id in &known {
        wait_terminal_chaotic(&server, id, Duration::from_secs(120));
    }

    // Disarm before the bookkeeping pass so the final stats/shutdown
    // round trips are exact, then check invariant 2: the books balance.
    fault::clear();
    let mut client = server.client();
    let stats = client.stats().expect("stats on a clean connection");
    let j = &stats.jobs;
    assert_eq!(
        j.completed + j.failed + j.cancelled,
        j.submitted,
        "books must balance: {j:?}"
    );
    assert_eq!(j.queued, 0, "nothing left queued: {j:?}");
    assert_eq!(j.running, 0, "nothing left running: {j:?}");
    assert!(j.submitted >= known.len() as u64, "{j:?}");
    if !cancelled_targets.is_empty() {
        // At least the cancels that landed on still-live jobs show up;
        // a cancel racing natural completion is legitimately a no-op.
        assert!(
            j.cancelled <= cancelled_targets.len() as u64,
            "more cancelled jobs than cancel calls: {j:?}"
        );
    }

    // Invariant 2b: the same books balance in the obs registry —
    // submitted == completed + failed + cancelled as deltas across this
    // run, mirroring the scheduler's own stats exactly, with the
    // queue/running gauges reading empty once everything is terminal.
    let obs_after = unigps::obs::metrics::snapshot();
    let delta = |name: &str| -> u64 {
        obs_after.counter(name).expect("registered counter")
            - obs_before.counter(name).expect("registered counter")
    };
    let submitted = delta("unigps_jobs_submitted_total");
    let terminal = delta("unigps_jobs_completed_total")
        + delta("unigps_jobs_failed_total")
        + delta("unigps_jobs_cancelled_total");
    assert_eq!(submitted, terminal, "obs books must balance under faults");
    assert_eq!(
        submitted, j.submitted,
        "obs counters mirror the scheduler's own books"
    );
    assert_eq!(obs_after.gauge("unigps_queue_depth"), Some(0));
    assert_eq!(obs_after.gauge("unigps_jobs_running"), Some(0));
    // Client-side retry accounting comes from the counters, not from
    // timing inference: every idempotent replay is preceded by a
    // successful reconnect, so reconnects bound replays from above.
    let replays = delta("unigps_client_replays_status_total")
        + delta("unigps_client_replays_wait_total")
        + delta("unigps_client_replays_result_total")
        + delta("unigps_client_replays_stats_total")
        + delta("unigps_client_replays_cancel_total");
    let reconnects = delta("unigps_client_reconnects_total");
    assert!(
        reconnects >= replays,
        "reconnects ({reconnects}) must bound idempotent replays ({replays})"
    );

    // Invariant 3: clean drain — shutdown returns, the server thread
    // joins, the socket file is gone.
    client.shutdown().expect("shutdown");
    drop(client);
    let socket = server.socket.clone();
    server.join();
    assert!(!socket.exists(), "socket file removed on shutdown");

    // Invariant 4: no leaked threads. Handler threads exit with their
    // connections, runners and the watchdog are joined by the drain;
    // give detached teardown a moment to settle. The +2 slack covers the
    // two sibling tests' harness threads (parked on CHAOS_LOCK until
    // this test returns) — a real leak is a dozen handler/runner
    // threads, not two.
    if let Some(baseline) = baseline_threads {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let now = thread_count().expect("thread count stays readable");
            if now <= baseline + 2 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "thread leak: {now} threads alive, baseline {baseline}"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

/// Control leg: with every failpoint disarmed the same burst completes
/// with zero failures — proving the harness itself (retry helpers,
/// accounting) injects no faults of its own.
#[test]
fn the_same_burst_is_clean_with_failpoints_disarmed() {
    let _g = locked();
    fault::clear();
    let server = start_server();

    let spec = "kind = rmat\nvertices = 256\nedges = 1024\nseed = 11\nworkers = 2\nalgo = sssp";
    let mut client = server.client();
    let mut ids = Vec::new();
    for _ in 0..8 {
        ids.push(client.submit(spec).expect("clean submit"));
    }
    for id in ids {
        client
            .wait(id, Duration::from_secs(120))
            .expect("clean job completes");
    }
    let stats = client.stats().expect("stats");
    assert_eq!(stats.jobs.failed, 0, "{:?}", stats.jobs);
    assert_eq!(stats.jobs.completed, 8);

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}

/// The dataset spec evolving under fire in the ingest leg (and the
/// seeded graph it resolves to, for computing applicable batches).
const INGEST_SPEC: &str = "kind = rmat\nvertices = 256\nedges = 1024\nseed = 11\nworkers = 2";

fn ingest_source() -> DatasetRef {
    DatasetRef::Synthetic {
        kind: "rmat".into(),
        vertices: 256,
        edges: 1024,
        seed: 11,
    }
}

/// `count` edge pairs absent from `g` (and distinct from each other), so
/// every batch built from them is guaranteed applicable at any epoch of
/// this run (each pair is added at most once).
fn absent_pairs(g: &unigps::graph::Graph, count: usize) -> Vec<(u32, u32)> {
    let topo = g.topology();
    let n = topo.num_vertices() as u32;
    let mut out = Vec::new();
    'scan: for u in 0..n {
        for v in 0..n {
            if u != v && topo.out_edges(u).all(|(_, t)| t != v) {
                out.push((u, v));
                if out.len() == count {
                    break 'scan;
                }
            }
        }
    }
    assert_eq!(out.len(), count, "graph too dense for the fixture");
    out
}

/// Ingest under fire: with `ingest-apply` armed at 50 %, single-edge
/// delta batches are driven through [`Client::ingest`], retrying each
/// until it lands. A failed apply surfaces the typed injected error and
/// leaves the generation chain untouched — so the retry applies against
/// the same parent and committed epochs come out dense and monotone
/// (1, 2, 3, …) with the invalidation books balancing exactly: every
/// commit supersedes precisely the resident older epochs, failures
/// supersede nothing.
#[test]
fn failed_ingests_leave_the_generation_untouched_and_books_balanced() {
    let _g = locked();
    fault::clear();
    let server = start_server();

    // Baseline job over a clean transport: generation 0 becomes resident
    // and the books start from a known state.
    let mut client = server.client();
    let id = client
        .submit(&format!("{INGEST_SPEC}\nalgo = sssp"))
        .expect("baseline submit");
    client.wait(id, Duration::from_secs(120)).expect("baseline job");

    // Arm ONLY the apply failpoint: the transport stays reliable, so
    // every error below is the apply dying mid-ingest, not chaos noise.
    fault::activate("seed=7;ingest-apply=error@0.5").expect("chaos spec parses");

    let parent = Session::builder().build().generate("rmat", 256, 1024, 11);
    // The last pair is reserved for the post-chaos ingest below; the
    // loop never touches it, so that batch is applicable at any epoch.
    let pairs = absent_pairs(&parent, 41);
    let mut committed: u64 = 0;
    let mut failures: u64 = 0;
    for &(u, v) in &pairs[..40] {
        // Enough evidence once both outcomes have been exercised.
        if committed >= 8 && failures >= 1 {
            break;
        }
        let batch = DeltaBatch::new(ingest_source(), vec![(u, v, 1.0)], vec![])
            .expect("valid batch");
        let text = batch.to_text();
        loop {
            match client.ingest(&text) {
                Ok(receipt) => {
                    committed += 1;
                    // Dense, monotone epochs: a failed attempt consumed
                    // no epoch, so the k-th commit is exactly epoch k.
                    assert_eq!(receipt.epoch, committed, "epochs must stay dense");
                    assert_eq!(receipt.edges_added, 1);
                    assert_eq!(receipt.edges_removed, 0);
                    break;
                }
                Err(e) => {
                    failures += 1;
                    assert!(matches!(e, UniGpsError::Serve(_)), "{e:?}");
                    assert!(e.to_string().contains("fault injected at 'ingest-apply'"), "{e}");
                    assert!(
                        failures < 200,
                        "a 50% failpoint cannot fail {failures} times in a row"
                    );
                }
            }
        }
    }
    assert!(committed >= 8, "the retry loop must land its batches");
    assert!(failures >= 1, "the 50% failpoint must fire across {committed}+ applies");

    fault::clear();
    // Books balance exactly: the k-th commit supersedes the k resident
    // older epochs of this dataset (nothing evicted at an unbounded
    // budget, no derived variants in play), failed applies supersede
    // nothing; every attempt — failed or not — resolved the parent from
    // cache, and only commits inserted a new snapshot.
    let stats = client.stats().expect("stats on a clean connection");
    assert_eq!(
        stats.cache.invalidated,
        committed * (committed + 1) / 2,
        "failed ingests must not invalidate: {committed} commits, {failures} failures"
    );
    assert_eq!(stats.cache.loads, 1 + committed, "one base load + one per commit");
    assert_eq!(stats.cache.misses, 1 + committed);
    assert_eq!(stats.cache.hits, committed + failures, "every attempt hit the parent");
    assert_eq!(stats.cache.evictions, 0);

    // The chain length is exactly the commit count, proven over the
    // wire: a pin at the committed epoch answers, one past it fails
    // typed at run time.
    let id = client
        .submit(&format!("{INGEST_SPEC}\nalgo = sssp\ngeneration = {committed}"))
        .expect("pin at the committed epoch admits");
    client
        .wait(id, Duration::from_secs(120))
        .expect("pinned job completes");
    let id = client
        .submit(&format!("{INGEST_SPEC}\nalgo = sssp\ngeneration = {}", committed + 1))
        .expect("over-pin admits (it may race a future ingest)");
    let err = client.wait(id, Duration::from_secs(60)).unwrap_err();
    assert!(err.to_string().contains("has no generation"), "{err}");

    // Disarmed, the next ingest continues the chain where it left off.
    let &(u, v) = pairs.last().expect("fixture has pairs");
    let batch = DeltaBatch::new(ingest_source(), vec![(u, v, 1.0)], vec![]).expect("valid batch");
    let receipt = client.ingest(&batch.to_text()).expect("clean ingest");
    assert_eq!(receipt.epoch, committed + 1);

    let stats = client.stats().expect("stats");
    let j = &stats.jobs;
    assert_eq!(j.completed + j.failed + j.cancelled, j.submitted, "books: {j:?}");
    assert_eq!(j.failed, 1, "exactly the over-pinned job failed: {j:?}");

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}
