//! The unified `Client` surface across every transport: in-process
//! [`LocalClient`], Unix-domain-socket [`ServeClient`] and
//! token-authenticated TCP [`RemoteClient`] must be interchangeable —
//! same plan, bit-identical result (f64 compared by bits) — and the
//! chunked result stream must carry tables of any size, including the
//! sizes the old single-frame protocol answered with a typed ERR.

use std::io::{BufReader, BufWriter};
use std::sync::Arc;
use std::time::Duration;
use unigps::client::{Client, LocalClient};
use unigps::distributed::metrics::RunMetrics;
use unigps::engine::{EngineKind, RunOptions, RunResult};
use unigps::error::UniGpsError;
use unigps::ipc::shm::ShmMap;
use unigps::ipc::socket_rpc::{read_frame, write_frame, MAX_FRAME_LEN};
use unigps::operators::{run_operator, Operator};
use unigps::serve::jobs::{decode_result, encode_result};
use unigps::serve::transport::{
    decode_error, read_result_stream, write_result_stream, MAX_RESULT_LEN,
};
use unigps::serve::{method, RemoteClient, ServeClient, ServeConfig, Server};
use unigps::session::Session;
use unigps::util::propcheck;
use unigps::vcprog::Column;

const TOKEN: &str = "transports-test-token";
const VERTICES: usize = 384;
const EDGES: usize = 1536;
const SEED: u64 = 1207;

fn spec() -> String {
    format!(
        "kind = rmat\nvertices = {VERTICES}\nedges = {EDGES}\nseed = {SEED}\n\
         workers = 2\nalgo = pagerank\niterations = 6\nengine = pregel"
    )
}

fn serve_cfg(tag: &str) -> ServeConfig {
    let mut cfg = ServeConfig::new(ShmMap::unique_path(tag));
    cfg.slots = 2;
    cfg.total_workers = 4; // per-job share = 2, matching the spec
    cfg.cache_budget = usize::MAX;
    cfg.tcp = Some("127.0.0.1:0".into());
    cfg.token = Some(TOKEN.into());
    cfg
}

fn bits_identical(a: &RunResult, b: &RunResult) -> bool {
    a.columns.len() == b.columns.len()
        && a.columns.iter().zip(&b.columns).all(|((an, ac), (bn, bc))| {
            an == bn
                && match (ac, bc) {
                    (Column::I64(x), Column::I64(y)) => x == y,
                    (Column::F64(x), Column::F64(y)) => {
                        x.len() == y.len()
                            && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                    }
                    _ => false,
                }
        })
}

/// Submit the shared spec through `client` and return the result.
fn run_through(client: &mut dyn Client) -> Arc<RunResult> {
    let id = client.submit(&spec()).expect("submit");
    client.wait(id, Duration::from_secs(120)).expect("job finishes")
}

/// The acceptance matrix: the same plan over TCP (valid token), over the
/// Unix socket, and through the in-process `LocalClient` returns
/// f64-bit-identical tables — and all three match a direct `run_operator`
/// call with the scheduler's effective options.
#[test]
fn local_uds_and_tcp_clients_are_interchangeable() {
    let cfg = serve_cfg("cli-tri");
    let socket = cfg.socket.clone();
    let local_cfg = cfg.clone();
    let server = Server::bind(Session::builder().build(), cfg).expect("bind");
    let tcp_addr = server.tcp_addr().expect("tcp listener bound");
    let handle = std::thread::spawn(move || server.run().expect("serve loop"));

    let mut uds = ServeClient::connect(&socket).expect("uds connect");
    let mut tcp =
        RemoteClient::connect_tcp(&tcp_addr.to_string(), TOKEN).expect("tcp connect + hello");
    let mut local = LocalClient::with_config(Session::builder().build(), &local_cfg);

    let via_uds = run_through(&mut uds);
    let via_tcp = run_through(&mut tcp);
    let via_local = run_through(&mut local);

    // Ground truth: the direct engine call with the split worker count.
    let graph = Session::builder().build().generate("rmat", VERTICES, EDGES, SEED);
    let direct = run_operator(
        &graph,
        &Operator::PageRank { iterations: 6 },
        EngineKind::Pregel,
        &RunOptions::default().with_workers(2),
    )
    .expect("direct run");

    assert!(bits_identical(&via_uds, &direct), "uds diverged from direct");
    assert!(bits_identical(&via_tcp, &direct), "tcp diverged from direct");
    assert!(bits_identical(&via_local, &direct), "local diverged from direct");

    // WAIT long-poll path: a delayed job blocks the waiter through its
    // delay, and a too-short wait is a typed timeout naming the state.
    let id = tcp.submit(&format!("{}\ndelay_ms = 300", spec())).expect("delayed submit");
    let t = std::time::Instant::now();
    tcp.wait(id, Duration::from_secs(120)).expect("delayed job");
    assert!(t.elapsed() >= Duration::from_millis(280), "waited through the delay");

    local.shutdown().expect("local shutdown");
    uds.shutdown().expect("server shutdown");
    drop(uds);
    drop(tcp);
    handle.join().expect("server thread");
}

/// A bad token is rejected with the typed auth error *during the
/// handshake* — before any method frame, so no job can ever be admitted
/// from an unauthenticated connection — and a raw TCP peer that skips
/// HELLO entirely gets the same typed rejection and a closed connection.
#[test]
fn tcp_auth_failures_are_typed_and_precede_admission() {
    let cfg = serve_cfg("cli-auth");
    let socket = cfg.socket.clone();
    let server = Server::bind(Session::builder().build(), cfg).expect("bind");
    let tcp_addr = server.tcp_addr().expect("tcp listener bound");
    let handle = std::thread::spawn(move || server.run().expect("serve loop"));

    // Wrong token: connect_tcp performs HELLO and must surface Auth.
    let err = RemoteClient::connect_tcp(&tcp_addr.to_string(), "wrong-token").unwrap_err();
    assert!(matches!(err, UniGpsError::Auth(_)), "typed auth error, got {err:?}");
    assert!(err.to_string().contains("bad token"), "{err}");

    // No HELLO at all: the first method frame is answered with a typed
    // Auth ERR and the connection closes without dispatching anything.
    let stream = std::net::TcpStream::connect(tcp_addr).expect("raw connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = BufWriter::new(stream);
    write_frame(&mut writer, method::SUBMIT, spec().as_bytes()).expect("write submit");
    let (head, payload) = read_frame(&mut reader).expect("read reply");
    assert_eq!(head, unigps::ipc::protocol::status::ERR);
    let err = decode_error(&payload);
    assert!(matches!(err, UniGpsError::Auth(_)), "{err:?}");
    assert!(err.to_string().contains("HELLO"), "{err}");
    // The server hung up after the rejection: the next read is EOF.
    assert!(read_frame(&mut reader).is_err(), "connection closed after auth failure");

    // Nothing was admitted by either attempt.
    let mut good = ServeClient::connect(&socket).expect("uds connect");
    let stats = good.stats().expect("stats");
    assert_eq!(stats.jobs.submitted, 0, "auth failures admit nothing");
    assert_eq!(stats.jobs.rejected, 0, "rejections counter untouched by auth");

    good.shutdown().expect("shutdown");
    drop(good);
    handle.join().expect("server thread");
}

/// With a deliberately tiny chunk size the engine's own result spans
/// many RESULT_CHUNK frames on the live wire — and still reassembles
/// bit-exact on both transports.
#[test]
fn multi_chunk_results_reassemble_bit_exact_on_both_transports() {
    let mut cfg = serve_cfg("cli-chunk");
    cfg.chunk_len = 64; // a ~6 KiB table -> ~100 chunks
    let socket = cfg.socket.clone();
    let server = Server::bind(Session::builder().build(), cfg).expect("bind");
    let tcp_addr = server.tcp_addr().expect("tcp listener bound");
    let handle = std::thread::spawn(move || server.run().expect("serve loop"));

    let mut uds = ServeClient::connect(&socket).expect("uds connect");
    let mut tcp = RemoteClient::connect_tcp(&tcp_addr.to_string(), TOKEN).expect("tcp connect");
    let via_uds = run_through(&mut uds);
    let via_tcp = run_through(&mut tcp);
    assert!(
        bits_identical(&via_uds, &via_tcp),
        "chunked reassembly diverged between transports"
    );
    assert!(via_uds.column("rank").is_some());

    uds.shutdown().expect("shutdown");
    drop(uds);
    drop(tcp);
    handle.join().expect("server thread");
}

/// The regression the redesign exists for: a result table whose encoding
/// exceeds `MAX_FRAME_LEN` — which the old single-frame protocol could
/// only answer with a typed ERR — now streams through the chunk codec
/// bit-exact.
#[test]
fn result_over_max_frame_len_streams_where_it_used_to_err() {
    // One f64 column pushes the encoding past the frame cap.
    let values: Vec<f64> = (0..(MAX_FRAME_LEN / 8 + 1024))
        .map(|i| (i as f64).sqrt() * if i % 3 == 0 { -1.0 } else { 1.0 })
        .collect();
    let big = RunResult {
        columns: vec![("rank".into(), Column::F64(values))],
        metrics: RunMetrics {
            supersteps: 7,
            workers: 4,
            converged: true,
            ..Default::default()
        },
    };
    let encoded = encode_result(&big);
    assert!(
        encoded.len() > MAX_FRAME_LEN,
        "table must exceed the single-frame cap to exercise the regression"
    );

    // The historical failure mode, pinned: one frame cannot carry it.
    let mut sink: Vec<u8> = Vec::new();
    let err = write_frame(&mut sink, 0, &encoded).unwrap_err();
    assert!(matches!(err, UniGpsError::Ipc(_)), "{err:?}");

    // The streaming path carries it fine, with the default chunk size.
    let mut wire: Vec<u8> = Vec::new();
    write_result_stream(&mut wire, &encoded, ServeConfig::in_process().chunk_len)
        .expect("stream write");
    let reassembled = read_result_stream(&mut wire.as_slice()).expect("stream read");
    assert_eq!(reassembled.len(), encoded.len());
    let back = decode_result(&reassembled).expect("decode");
    assert!(bits_identical(&back, &big), "reassembly must be bit-exact");
}

/// A failure mid-stream (here: a declared total over the client's cap,
/// with a leftover chunk frame behind it) poisons the client connection:
/// the next call fails fast with a typed desync error instead of
/// misreading the leftover chunk as its response.
#[test]
fn stream_failure_poisons_the_client_connection() {
    use std::os::unix::net::UnixListener;
    use unigps::ipc::protocol::{put_u32, put_u64};
    use unigps::serve::transport::reply;

    let path = ShmMap::unique_path("cli-poison");
    let listener = UnixListener::bind(&path).expect("bind mock");
    let srv = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        let mut writer = BufWriter::new(stream);
        let (m, _req) = read_frame(&mut reader).expect("result frame");
        assert_eq!(m, method::RESULT);
        // A hostile reply: over-cap total, plus a trailing chunk frame.
        let mut begin = Vec::new();
        put_u64(&mut begin, (MAX_RESULT_LEN as u64) + 1);
        put_u32(&mut begin, 1);
        write_frame(&mut writer, reply::RESULT_BEGIN, &begin).expect("begin");
        write_frame(&mut writer, reply::RESULT_CHUNK, &[7u8; 32]).expect("chunk");
        // Hold the connection open until the client disconnects.
        let _ = read_frame(&mut reader);
    });

    let mut client = ServeClient::connect(&path).expect("connect");
    let err = client.result(1).unwrap_err();
    assert!(err.to_string().contains("cap"), "{err}");
    // Poisoned: the follow-up never even reaches the wire.
    let err = client.status(1).unwrap_err();
    assert!(matches!(err, UniGpsError::Ipc(_)), "{err:?}");
    assert!(err.to_string().contains("desynchronized"), "{err}");
    drop(client);
    srv.join().expect("mock server");
    let _ = std::fs::remove_file(&path);
}

/// Property: the chunk codec round-trips arbitrary payloads bit-exact for
/// arbitrary chunk sizes (including chunk boundaries straddling the
/// payload length in every alignment).
#[test]
fn stream_codec_roundtrip_property() {
    propcheck::forall(
        propcheck::Config::new(96, 0x5EED_CAFE),
        |rng| {
            let len = rng.usize_below(8192);
            let chunk = 1 + rng.usize_below(300);
            let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            (payload, chunk)
        },
        |(payload, chunk)| {
            let mut wire: Vec<u8> = Vec::new();
            write_result_stream(&mut wire, payload, *chunk)
                .map_err(|e| format!("write failed: {e}"))?;
            let back = read_result_stream(&mut wire.as_slice())
                .map_err(|e| format!("read failed: {e}"))?;
            if back != *payload {
                return Err(format!(
                    "roundtrip mismatch at len {} chunk {}",
                    payload.len(),
                    chunk
                ));
            }
            Ok(())
        },
    );
    // Sanity on the guard: the codec never accepts a declared total over
    // the stream cap (checked in unit tests too; this pins the constant).
    assert!(MAX_RESULT_LEN > MAX_FRAME_LEN);
}
