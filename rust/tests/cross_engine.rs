//! Integration: cross-engine equivalence — the paper's "Write Once, Run
//! Anywhere" claim, checked for every built-in program over seeded random
//! graphs and adversarial topologies.

use unigps::engine::validate::{approx, check_all_engines, exact};
use unigps::engine::{run_typed, EngineKind, RunOptions};
use unigps::graph::builder::from_pairs;
use unigps::graph::generate;
use unigps::operators::symmetrized;
use unigps::util::propcheck::{forall, Config};
use unigps::vcprog::programs::*;

fn opts() -> RunOptions {
    RunOptions::default().with_workers(3)
}

#[test]
fn sssp_equivalent_on_random_graphs() {
    forall(
        Config::new(12, 0x55),
        |rng| {
            let n = 10 + rng.usize_below(150);
            let m = n * (1 + rng.usize_below(6));
            generate::random_for_tests(n, m, rng.next_u64())
        },
        |g| {
            check_all_engines(g, &SsspBellmanFord::new(0), &opts(), exact)
                .map(|_| ())
                .map_err(|e| e.to_string())
        },
    );
}

#[test]
fn cc_equivalent_on_random_graphs() {
    forall(
        Config::new(12, 0x66),
        |rng| {
            let n = 10 + rng.usize_below(120);
            let m = n * (1 + rng.usize_below(4));
            let g = generate::random_for_tests(n, m, rng.next_u64());
            symmetrized(&g)
        },
        |g| {
            check_all_engines(g, &ConnectedComponents::new(), &opts(), exact)
                .map(|_| ())
                .map_err(|e| e.to_string())
        },
    );
}

#[test]
fn bfs_equivalent_on_random_graphs() {
    forall(
        Config::new(10, 0x77),
        |rng| {
            let n = 10 + rng.usize_below(100);
            generate::random_for_tests(n, n * 3, rng.next_u64())
        },
        |g| {
            check_all_engines(g, &Bfs::new(0), &opts(), exact)
                .map(|_| ())
                .map_err(|e| e.to_string())
        },
    );
}

#[test]
fn pagerank_equivalent_within_fp_tolerance() {
    forall(
        Config::new(8, 0x88),
        |rng| {
            let n = 10 + rng.usize_below(100);
            generate::random_for_tests(n, n * 4, rng.next_u64())
        },
        |g| {
            let prog = PageRank::new(g.num_vertices(), 8);
            let mut o = opts();
            o.max_iter = prog.rounds();
            let cmp = approx(1e-9);
            check_all_engines(g, &prog, &o, |a, b| cmp(&a.rank, &b.rank))
                .map(|_| ())
                .map_err(|e| e.to_string())
        },
    );
}

#[test]
fn degree_and_kcore_and_reachability_equivalent() {
    forall(
        Config::new(8, 0x99),
        |rng| {
            let n = 8 + rng.usize_below(80);
            let g = generate::random_for_tests(n, n * 3, rng.next_u64());
            symmetrized(&g)
        },
        |g| {
            check_all_engines(g, &DegreeCount::new(), &opts(), exact)
                .map_err(|e| format!("degree: {e}"))?;
            check_all_engines(g, &KCore::new(3), &opts(), exact)
                .map_err(|e| format!("kcore: {e}"))?;
            check_all_engines(g, &Reachability::new(0), &opts(), exact)
                .map_err(|e| format!("reachability: {e}"))?;
            Ok(())
        },
    );
}

#[test]
fn triangle_equivalent_and_matches_oracle() {
    forall(
        Config::new(6, 0xAA),
        |rng| {
            let n = 8 + rng.usize_below(40);
            let g = generate::random_for_tests(n, n * 3, rng.next_u64());
            symmetrized(&g)
        },
        |g| {
            let props = check_all_engines(g, &TriangleCount::new(), &opts(), exact)
                .map_err(|e| e.to_string())?;
            let hits: Vec<i64> = props.iter().map(|p| p.hits as i64).collect();
            let got = TriangleCount::global_from_hits(&hits);
            let want = unigps::engine::baselines::triangle_count(g);
            if got != want {
                return Err(format!("triangles {got} != oracle {want}"));
            }
            Ok(())
        },
    );
}

#[test]
fn lpa_equivalent_across_engines() {
    // LPA is iteration-count-deterministic; engines must agree exactly.
    forall(
        Config::new(6, 0xAB),
        |rng| {
            let n = 8 + rng.usize_below(60);
            let g = generate::random_for_tests(n, n * 3, rng.next_u64());
            symmetrized(&g)
        },
        |g| {
            let prog = LabelPropagation::new(4);
            let mut o = opts();
            o.max_iter = prog.rounds();
            check_all_engines(g, &prog, &o, exact)
                .map(|_| ())
                .map_err(|e| e.to_string())
        },
    );
}

#[test]
fn adversarial_topologies() {
    // Star (extreme skew), grid (long diameter), singleton + isolated.
    let graphs = vec![
        generate::star(200, true),
        generate::grid(20, 20, true),
        from_pairs(true, &[(0, 0)]), // single self-loop
    ];
    for g in &graphs {
        check_all_engines(g, &SsspBellmanFord::new(0), &opts(), exact).unwrap();
        check_all_engines(&symmetrized(g), &ConnectedComponents::new(), &opts(), exact).unwrap();
    }
}

#[test]
fn partition_and_worker_invariance() {
    use unigps::graph::partition::PartitionStrategy;
    let g = generate::random_for_tests(150, 900, 0xBEEF);
    let reference = run_typed(EngineKind::Pregel, &g, &SsspBellmanFord::new(0), &opts())
        .unwrap()
        .props;
    for workers in [1, 2, 5, 8] {
        for strat in [
            PartitionStrategy::Hash,
            PartitionStrategy::Range,
            PartitionStrategy::EdgeBalanced,
        ] {
            let mut o = RunOptions::default().with_workers(workers);
            o.partition = strat;
            for kind in [EngineKind::Pregel, EngineKind::Gas, EngineKind::PushPull] {
                let got = run_typed(kind, &g, &SsspBellmanFord::new(0), &o).unwrap().props;
                assert_eq!(got, reference, "{kind} w={workers} {strat:?}");
            }
        }
    }
}

#[test]
fn merge_algebra_laws_hold() {
    // merge(m, empty) == m and merge(a,b) == merge(b,a) for built-ins.
    let sssp = SsspBellmanFord::new(0);
    let cc = ConnectedComponents::new();
    let pr = PageRank::new(100, 5);
    forall(
        Config::new(64, 0xCC),
        |rng| (rng.next_u64() as i64 >> 1, rng.next_u64() as i64 >> 1, rng.next_u64()),
        |(a, b, s)| {
            use unigps::vcprog::VCProg;
            if sssp.merge_message(a, b) != sssp.merge_message(b, a) {
                return Err("sssp merge not commutative".into());
            }
            if sssp.merge_message(a, &sssp.empty_message()) != *a {
                return Err("sssp empty not identity".into());
            }
            let (la, lb) = ((*a as u32) >> 1, (*b as u32) >> 1);
            if cc.merge_message(&la, &lb) != cc.merge_message(&lb, &la) {
                return Err("cc merge not commutative".into());
            }
            if cc.merge_message(&la, &cc.empty_message()) != la {
                return Err("cc empty not identity".into());
            }
            let (fa, fb) = ((*s as f64) * 1e-19, (la as f64) * 1e-3);
            if pr.merge_message(&fa, &pr.empty_message()) != fa {
                return Err("pr empty not identity".into());
            }
            if pr.merge_message(&fa, &fb) != pr.merge_message(&fb, &fa) {
                return Err("pr merge not commutative".into());
            }
            Ok(())
        },
    );
}
