//! Property suite for the evolving-graph incremental operators
//! (`unigps::delta::incremental`, contract in `docs/evolving.md`).
//!
//! For 32 random (graph, delta batch) pairs, the incremental operators on
//! generation N+1 must match a from-scratch engine run on the
//! materialized child exactly — PageRank ranks bit-identical as `f64`s
//! (compared via `to_bits`, so `-0.0` and NaN payloads count), CC labels
//! equal — across all three partition strategies, pipeline on/off and
//! combiner on/off.

use unigps::delta::incremental::{
    cc_labels, incremental_cc, incremental_pagerank, pagerank_trace,
};
use unigps::delta::DeltaBatch;
use unigps::engine::{pregel, RunOptions};
use unigps::graph::generate::random_for_tests;
use unigps::graph::partition::PartitionStrategy;
use unigps::graph::Graph;
use unigps::plan::DatasetRef;
use unigps::vcprog::programs::{ConnectedComponents, PageRank};
use unigps::vcprog::VertexId;

const GRAPHS: u64 = 32;
const ITERATIONS: u32 = 6;

/// Deterministic splitmix64 for batch construction — the suite must
/// replay identically run to run.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
}

fn source_for(n: usize, m: usize, seed: u64) -> DatasetRef {
    DatasetRef::Synthetic {
        kind: "er".into(),
        vertices: n,
        edges: m,
        seed,
    }
}

/// Distinct `(src, dst)` pairs present in the graph, in row order (the
/// generators emit multigraphs; a remove deletes every occurrence).
fn present_pairs(g: &Graph) -> Vec<(VertexId, VertexId)> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for u in 0..g.num_vertices() as VertexId {
        for (_eid, v) in g.topology().out_edges(u) {
            if seen.insert((u, v)) {
                out.push((u, v));
            }
        }
    }
    out
}

/// A random valid batch against `parent`: up to 4 removes of present
/// pairs (skipped entirely on every third draw, so incremental CC runs
/// its union-find merge path and not just the removal fallback) and up
/// to 4 adds of pairs absent from the parent.
fn random_batch(parent: &Graph, source: DatasetRef, rng: &mut Rng) -> DeltaBatch {
    let n = parent.num_vertices() as u64;
    let present = present_pairs(parent);
    let present_set: std::collections::HashSet<_> = present.iter().copied().collect();
    let mut removes = Vec::new();
    if rng.below(3) != 0 {
        let want = (1 + rng.below(4) as usize).min(present.len());
        let mut chosen = std::collections::HashSet::new();
        for _ in 0..want * 8 {
            if chosen.len() >= want {
                break;
            }
            let i = rng.below(present.len() as u64) as usize;
            if chosen.insert(i) {
                removes.push(present[i]);
            }
        }
    }
    let mut adds = Vec::new();
    let mut added = std::collections::HashSet::new();
    let want = 1 + rng.below(4) as usize;
    for _ in 0..want * 32 {
        if adds.len() >= want {
            break;
        }
        let (u, v) = (rng.below(n) as VertexId, rng.below(n) as VertexId);
        if !present_set.contains(&(u, v)) && added.insert((u, v)) {
            let w = 1.0 + rng.below(8) as f64;
            adds.push((u, v, w));
        }
    }
    if adds.is_empty() && removes.is_empty() {
        // Degenerate draw on a dense tiny graph: remove one present edge
        // (the generated sizes always have at least one).
        removes.push(present[0]);
    }
    DeltaBatch::new(source, adds, removes).expect("random batch is valid")
}

/// From-scratch engine ranks — the ground truth the incremental path
/// must hit bit-for-bit.
fn engine_ranks(g: &Graph, opts: &RunOptions) -> Vec<f64> {
    let pr = PageRank::new(g.num_vertices(), ITERATIONS);
    let mut o = opts.clone();
    o.max_iter = opts.max_iter.min(pr.rounds());
    let run = pregel::run(g, &pr, &o).expect("engine pagerank");
    run.props.iter().map(|p| p.rank).collect()
}

/// From-scratch engine CC labels (the `cc` workload runs on the
/// symmetrized graph and emits min-vertex-id labels as `i64`).
fn engine_cc(g: &Graph, opts: &RunOptions) -> Vec<i64> {
    let sym = unigps::operators::symmetrized(g);
    let run = pregel::run(&sym, &ConnectedComponents::new(), opts).expect("engine cc");
    run.props.iter().map(|&l| l as i64).collect()
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Every execution shape the contract covers: 3 partition strategies ×
/// pipeline on/off × combiner on/off.
fn configs() -> Vec<RunOptions> {
    let mut out = Vec::new();
    for strat in [
        PartitionStrategy::Hash,
        PartitionStrategy::Range,
        PartitionStrategy::EdgeBalanced,
    ] {
        for pipeline in [false, true] {
            for combiner in [false, true] {
                let mut opts = RunOptions::default().with_workers(3);
                opts.partition = strat;
                opts.pipeline = pipeline;
                opts.combiner = combiner;
                out.push(opts);
            }
        }
    }
    out
}

fn graph_shape(seed: u64) -> (usize, usize) {
    let n = 16 + (seed as usize * 7) % 33; // 16..=48 vertices
    let m = 3 * n + (seed as usize * 13) % (2 * n);
    (n, m)
}

#[test]
fn incremental_pagerank_is_bit_identical_to_scratch() {
    for seed in 0..GRAPHS {
        let (n, m) = graph_shape(seed);
        let parent = random_for_tests(n, m, 1000 + seed);
        let mut rng = Rng(0xD00D ^ seed);
        let batch = random_batch(&parent, source_for(n, m, 1000 + seed), &mut rng);
        let (child, _removed) = batch.apply(&parent).expect("batch applies");
        for opts in configs() {
            let parent_trace = pagerank_trace(&parent, ITERATIONS, &opts);
            let inc = incremental_pagerank(&parent_trace, &child, &batch, ITERATIONS, &opts);
            let scratch = engine_ranks(&child, &opts);
            assert_eq!(
                bits(inc.final_ranks()),
                bits(&scratch),
                "seed {seed}: {:?} pipeline={} combiner={}",
                opts.partition,
                opts.pipeline,
                opts.combiner
            );
        }
    }
}

#[test]
fn incremental_cc_matches_scratch() {
    for seed in 0..GRAPHS {
        let (n, m) = graph_shape(seed);
        let parent = random_for_tests(n, m, 2000 + seed);
        let mut rng = Rng(0xCC00 ^ seed);
        let batch = random_batch(&parent, source_for(n, m, 2000 + seed), &mut rng);
        let (child, _removed) = batch.apply(&parent).expect("batch applies");
        let parent_labels = cc_labels(&parent);
        let inc = incremental_cc(&parent_labels, &child, &batch);
        // From-scratch union-find on the materialized child...
        assert_eq!(inc, cc_labels(&child), "seed {seed}");
        // ...and the engine itself, across every execution shape.
        for opts in configs() {
            assert_eq!(
                inc,
                engine_cc(&child, &opts),
                "seed {seed}: {:?} pipeline={} combiner={}",
                opts.partition,
                opts.pipeline,
                opts.combiner
            );
        }
    }
}
