//! Integration: adversarial/edge-case inputs through every engine.

use unigps::engine::{run_typed, EngineKind, RunOptions};
use unigps::graph::builder::{from_pairs, GraphBuilder};
use unigps::operators::{symmetrized, Operator, OperatorBuilder};
use unigps::vcprog::programs::sssp::{SsspBellmanFord, INF};
use unigps::vcprog::programs::{ConnectedComponents, DegreeCount, PageRank};

fn opts(w: usize) -> RunOptions {
    RunOptions::default().with_workers(w)
}

#[test]
fn single_vertex_no_edges() {
    let mut b: GraphBuilder<f64> = GraphBuilder::new(true);
    b.ensure_vertices(1);
    let g = b.build().unwrap();
    for kind in EngineKind::vcprog_engines() {
        let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts(2)).unwrap();
        assert_eq!(r.props, vec![0], "{kind}");
        assert!(r.metrics.converged);
    }
}

#[test]
fn self_loops_deliver_next_round() {
    // Self-loop on the root: SSSP must not livelock (dist+w ≥ dist ⇒ no
    // improvement ⇒ convergence).
    let mut b = GraphBuilder::new(true);
    b.add_edge(0, 0, 1.0);
    b.add_edge(0, 1, 2.0);
    let g = b.build().unwrap();
    for kind in EngineKind::vcprog_engines() {
        let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts(2)).unwrap();
        assert_eq!(r.props, vec![0, 2], "{kind}");
        assert!(r.metrics.converged, "{kind}");
    }
}

#[test]
fn zero_weight_cycle_converges() {
    // 0 ⇄ 1 with zero weights: relaxation reaches a fixpoint, engines must
    // terminate (no strictly-improving update exists).
    let mut b = GraphBuilder::new(true);
    b.add_edge(0, 1, 0.0);
    b.add_edge(1, 0, 0.0);
    let g = b.build().unwrap();
    for kind in EngineKind::vcprog_engines() {
        let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts(2)).unwrap();
        assert_eq!(r.props, vec![0, 0], "{kind}");
        assert!(r.metrics.converged, "{kind}");
    }
}

#[test]
fn parallel_edges_counted_by_degree() {
    let mut b = GraphBuilder::new(true);
    b.add_edge(0, 1, 3.0);
    b.add_edge(0, 1, 7.0);
    let g = b.build().unwrap();
    for kind in EngineKind::vcprog_engines() {
        let r = run_typed(kind, &g, &DegreeCount::new(), &opts(2)).unwrap();
        assert_eq!(r.props[0].out, 2, "{kind}");
        assert_eq!(r.props[1].inn, 2, "{kind}");
        // And SSSP takes the cheaper parallel edge.
        let s = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts(2)).unwrap();
        assert_eq!(s.props[1], 3, "{kind}");
    }
}

#[test]
fn max_iter_zero_returns_init_state() {
    let g = from_pairs(true, &[(0, 1)]);
    let mut o = opts(2);
    o.max_iter = 0;
    for kind in EngineKind::vcprog_engines() {
        let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &o).unwrap();
        assert_eq!(r.props, vec![0, INF], "{kind}: no iterations → init state");
        assert_eq!(r.metrics.supersteps, 0, "{kind}");
    }
}

#[test]
fn more_workers_than_vertices() {
    let g = from_pairs(true, &[(0, 1), (1, 2)]);
    for kind in EngineKind::vcprog_engines() {
        let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts(64)).unwrap();
        assert_eq!(r.props, vec![0, 1, 2], "{kind}");
    }
}

#[test]
fn disconnected_forest_cc() {
    // 100 isolated vertices → 100 singleton components.
    let mut b: GraphBuilder<f64> = GraphBuilder::new(true);
    b.ensure_vertices(100);
    let g = b.build().unwrap();
    for kind in EngineKind::vcprog_engines() {
        let r = run_typed(kind, &g, &ConnectedComponents::new(), &opts(4)).unwrap();
        for (v, &label) in r.props.iter().enumerate() {
            assert_eq!(label, v as u32, "{kind}");
        }
    }
}

#[test]
fn dangling_mass_pagerank_consistent_across_engines() {
    // Dangling sink: engines must agree bit-for-bit on structure (rank of
    // dangling vertex keeps receiving, emits nothing).
    let g = from_pairs(true, &[(0, 1), (1, 2), (0, 2)]); // 2 is a sink
    let prog = PageRank::new(3, 15);
    let mut o = opts(2);
    o.max_iter = prog.rounds();
    let serial = run_typed(EngineKind::Serial, &g, &prog, &o).unwrap().props;
    for kind in [EngineKind::Pregel, EngineKind::Gas, EngineKind::PushPull] {
        let r = run_typed(kind, &g, &prog, &o).unwrap();
        for (a, b) in r.props.iter().zip(&serial) {
            assert!((a.rank - b.rank).abs() < 1e-12, "{kind}");
        }
    }
    // Sink rank exceeds sources' (it collects from both).
    assert!(serial[2].rank > serial[0].rank);
}

#[test]
fn operator_on_empty_graph() {
    let b: GraphBuilder<f64> = GraphBuilder::new(true);
    let g = b.build().unwrap();
    let r = OperatorBuilder::new(&g, Operator::ConnectedComponents)
        .engine(EngineKind::Pregel)
        .run()
        .unwrap();
    assert_eq!(r.column("component").unwrap().len(), 0);
}

#[test]
fn symmetrized_idempotent() {
    let g = from_pairs(true, &[(0, 1), (1, 0), (1, 2)]);
    let s1 = symmetrized(&g);
    let s2 = symmetrized(&s1);
    assert_eq!(s1.num_edges(), s2.num_edges());
    assert_eq!(s1.topology().csr().unwrap().1, s2.topology().csr().unwrap().1);
}
