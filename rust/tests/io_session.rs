//! Integration: unified graph I/O + session/config/CLI-facing surface.

use std::path::PathBuf;
use unigps::config::Config;
use unigps::engine::EngineKind;
use unigps::graph::io::Format;
use unigps::graph::record::{FieldType, RecordBuilder, Schema};
use unigps::session::Session;
use unigps::util::propcheck::{forall, Config as PropConfig};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("unigps-it-{}-{name}", std::process::id()));
    p
}

#[test]
fn round_trip_every_format_preserves_results() {
    // The M+N argument, end to end: results must be invariant under any
    // store→load cycle in any format.
    let session = Session::builder().workers(2).build();
    let g = session.generate("rmat", 512, 2048, 13);
    let want = session.sssp(&g, 0).run().unwrap();
    let want_d = want.column("distance").unwrap().as_i64().unwrap().to_vec();

    for (fmt, ext) in [
        (Format::EdgeList, "txt"),
        (Format::UniGraph, "json"),
        (Format::Binary, "bin"),
    ] {
        let p = tmp(&format!("roundtrip.{ext}"));
        fmt.store(&g, &p).unwrap();
        let loaded = session.load(&p).unwrap();
        assert_eq!(loaded.num_edges(), g.num_edges(), "{ext}");
        let got = session.sssp(&loaded, 0).run().unwrap();
        assert_eq!(
            got.column("distance").unwrap().as_i64().unwrap(),
            &want_d[..],
            "{ext}"
        );
        let _ = std::fs::remove_file(&p);
    }
}

#[test]
fn random_graph_io_roundtrip_property() {
    forall(
        PropConfig::new(8, 0xF0),
        |rng| {
            let n = 5 + rng.usize_below(100);
            unigps::graph::generate::random_for_tests(n, n * 2, rng.next_u64())
        },
        |g| {
            let p = tmp("prop.bin");
            Format::Binary.store(g, &p).map_err(|e| e.to_string())?;
            let back = Format::Binary.load(&p).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&p);
            if back.topology().csr() != g.topology().csr() {
                return Err("CSR changed across binary roundtrip".into());
            }
            if back.edge_props() != g.edge_props() {
                return Err("weights changed across binary roundtrip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn session_from_config_runs_operators() {
    let p = tmp("session.conf");
    std::fs::write(
        &p,
        "# test config\nengine = gemini\nworkers = 2\nmax_iter = 500\npartition = edge-balanced\n",
    )
    .unwrap();
    let session = Session::from_config_file(&p).unwrap();
    assert_eq!(session.default_engine(), EngineKind::PushPull);
    let g = session.generate("er", 300, 1200, 5);
    let r = session.cc(&g).run().unwrap();
    assert_eq!(r.column("component").unwrap().len(), 300);
    let _ = std::fs::remove_file(&p);
}

#[test]
fn config_overrides_and_errors() {
    let mut c = Config::parse("engine = pregel\nworkers = 4").unwrap();
    c.set("workers", "7");
    assert_eq!(c.get_usize("workers", 0).unwrap(), 7);
    assert!(Session::from_config(&Config::parse("engine = cobol").unwrap()).is_err());
    assert!(Session::from_config(&Config::parse("partition = diagonal").unwrap()).is_err());
}

#[test]
fn record_system_supports_paper_demo() {
    // The Fig 3 record-building dance.
    let schema = Schema::new(vec![("vid", FieldType::Long), ("distance", FieldType::Long)]);
    let mut rec = RecordBuilder::new(schema.clone())
        .set_long("vid", 7)
        .set_long("distance", i64::MAX)
        .build();
    assert_eq!(rec.get_long("distance").unwrap(), i64::MAX);
    rec.set_long("distance", 42).unwrap();
    // Wire round-trip (what the IPC layer ships).
    let mut buf = Vec::new();
    rec.encode(&mut buf);
    let mut pos = 0;
    let back = unigps::graph::record::Record::decode(&schema, &buf, &mut pos).unwrap();
    assert_eq!(back.get_long("distance").unwrap(), 42);
}

#[test]
fn store_tsv_output_table() {
    let session = Session::builder().workers(2).build();
    let g = session.generate("grid", 16, 0, 0);
    let r = session.bfs(&g, 0).run().unwrap();
    let p = tmp("out.tsv");
    r.store_tsv(&p).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    let mut lines = text.lines();
    assert_eq!(lines.next().unwrap(), "vid\thops");
    assert_eq!(text.lines().count(), g.num_vertices() + 1);
    let _ = std::fs::remove_file(&p);
}

#[test]
fn cli_binary_end_to_end() {
    let exe = env!("CARGO_BIN_EXE_unigps");
    // generate → info → run with output file.
    let gpath = tmp("cli-graph.bin");
    let out = std::process::Command::new(exe)
        .args(["generate", "--kind", "er", "--vertices", "200", "--edges", "800"])
        .args(["--out", gpath.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = std::process::Command::new(exe)
        .args(["info", "--graph", gpath.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("V=200"));

    let tsv = tmp("cli-out.tsv");
    let out = std::process::Command::new(exe)
        .args(["run", "--algo", "cc", "--graph", gpath.to_str().unwrap()])
        .args(["--engine", "gas", "--workers", "2", "--output", tsv.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(tsv.exists());

    // Unknown engine fails with a clean error.
    let out = std::process::Command::new(exe)
        .args(["run", "--engine", "mapreduce"])
        .output()
        .unwrap();
    assert!(!out.status.success());

    let _ = std::fs::remove_file(&gpath);
    let _ = std::fs::remove_file(&tsv);
}
