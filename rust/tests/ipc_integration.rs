//! Integration: the execution-isolation mechanism end to end — remote
//! programs over both transports, thread- and process-hosted runners,
//! concurrency, failure handling, and transparency across all engines.

use unigps::engine::{run_typed, EngineKind, RunOptions};
use unigps::graph::generate;
use unigps::ipc::remote_program::RemoteVCProg;
use unigps::ipc::Transport;
use unigps::operators::symmetrized;
use unigps::util::propcheck::{forall, Config};
use unigps::vcprog::programs::{ConnectedComponents, PageRank, SsspBellmanFord};
use unigps::vcprog::VCProg;

fn opts() -> RunOptions {
    RunOptions::default().with_workers(2)
}

#[test]
fn remote_sssp_matches_local_property() {
    forall(
        Config::new(5, 0xD0),
        |rng| {
            let n = 10 + rng.usize_below(60);
            generate::random_for_tests(n, n * 3, rng.next_u64())
        },
        |g| {
            let local = run_typed(EngineKind::Pregel, g, &SsspBellmanFord::new(0), &opts())
                .map_err(|e| e.to_string())?
                .props;
            let remote = RemoteVCProg::launch(
                SsspBellmanFord::new(0),
                "sssp root=0",
                2,
                Transport::ZeroCopyShm,
                true,
            )
            .map_err(|e| e.to_string())?;
            let got = run_typed(EngineKind::Pregel, g, &remote, &opts())
                .map_err(|e| e.to_string())?
                .props;
            remote.shutdown();
            if got != local {
                return Err("remote != local".into());
            }
            Ok(())
        },
    );
}

#[test]
fn remote_cc_over_socket_on_all_engines() {
    let g = symmetrized(&generate::random_for_tests(50, 250, 0xD1));
    let local = run_typed(EngineKind::Serial, &g, &ConnectedComponents::new(), &opts())
        .unwrap()
        .props;
    for kind in EngineKind::vcprog_engines() {
        let remote =
            RemoteVCProg::launch(ConnectedComponents::new(), "cc", 2, Transport::Socket, true)
                .unwrap();
        let got = run_typed(kind, &g, &remote, &opts()).unwrap().props;
        remote.shutdown();
        assert_eq!(got, local, "{kind}");
    }
}

#[test]
fn remote_pagerank_matches_local() {
    let g = generate::random_for_tests(60, 300, 0xD2);
    let n = g.num_vertices();
    let prog = PageRank::new(n, 6);
    let mut o = opts();
    o.max_iter = prog.rounds();
    let local = run_typed(EngineKind::Pregel, &g, &prog, &o).unwrap().props;
    let remote = RemoteVCProg::launch(
        prog,
        &format!("pagerank n={n} iters=6"),
        2,
        Transport::ZeroCopyShm,
        true,
    )
    .unwrap();
    let got = run_typed(EngineKind::Pregel, &g, &remote, &o).unwrap().props;
    remote.shutdown();
    for (a, b) in got.iter().zip(&local) {
        assert!((a.rank - b.rank).abs() < 1e-12, "{} vs {}", a.rank, b.rank);
    }
}

#[test]
fn remote_program_survives_concurrent_callers() {
    // Hammer one remote program from many threads simultaneously; every
    // call must return a correct merge result.
    let remote = std::sync::Arc::new(
        RemoteVCProg::launch(
            SsspBellmanFord::new(0),
            "sssp root=0",
            4,
            Transport::ZeroCopyShm,
            true,
        )
        .unwrap(),
    );
    std::thread::scope(|s| {
        for t in 0..8 {
            let remote = remote.clone();
            s.spawn(move || {
                for i in 0..200i64 {
                    let m = remote.merge_message(&(t * 1000 + i), &(i * 7));
                    assert_eq!(m, (t * 1000 + i).min(i * 7));
                }
            });
        }
    });
    assert!(remote.remote_calls() >= 1600);
    remote.shutdown();
}

#[test]
fn bad_spec_fails_cleanly() {
    let r = RemoteVCProg::launch(
        SsspBellmanFord::new(0),
        "not-a-program",
        1,
        Transport::ZeroCopyShm,
        true,
    );
    assert!(r.is_err(), "unknown program spec must fail launch");
}

#[test]
fn process_mode_round_trip() {
    // Spawn real child processes (requires the unigps binary; skip if the
    // binary isn't built yet).
    if std::process::Command::new(env!("CARGO_BIN_EXE_unigps"))
        .arg("version")
        .output()
        .is_err()
    {
        eprintln!("skipping: unigps binary unavailable");
        return;
    }
    std::env::set_var("UNIGPS_BIN", env!("CARGO_BIN_EXE_unigps"));
    let g = generate::random_for_tests(40, 160, 0xD4);
    let local = run_typed(EngineKind::Pregel, &g, &SsspBellmanFord::new(0), &opts())
        .unwrap()
        .props;
    for transport in [Transport::ZeroCopyShm, Transport::Socket] {
        let remote =
            RemoteVCProg::launch(SsspBellmanFord::new(0), "sssp root=0", 2, transport, false)
                .unwrap();
        let got = run_typed(EngineKind::Pregel, &g, &remote, &opts()).unwrap().props;
        remote.shutdown();
        assert_eq!(got, local, "{}", transport.name());
    }
}
