//! Schedule-exploring model checks for the runtime's concurrency kernel.
//!
//! The protocol under test is the superstep runtime's seal/drain handoff
//! plus its counting gates (`rust/src/engine/superstep.rs`,
//! `rust/src/distributed/comm.rs`), re-run here as a compact replica driven
//! by the in-house model checker (`unigps::util::model`): every atomic and
//! every traced plain access becomes a scheduling point, a deterministic
//! virtual scheduler explores interleavings, and vector clocks flag any
//! unsynchronized plain access.
//!
//! The replica tests run under plain `cargo test` — the model types are
//! always compiled, and a `Session` activates them explicitly. Building
//! with `RUSTFLAGS="--cfg unigps_model" cargo test --test model_check`
//! additionally swaps the whole `util::sync` facade to the model types and
//! enables the test driving the *real* `FlatBoard`. See
//! `docs/concurrency.md` for the protocol spec these assertions encode.

use std::cell::UnsafeCell;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use unigps::util::model::{trace_read, trace_write, AtomicU64, Explorer, Session};

/// Workers (= shards; the paper's one-partition-per-worker layout).
const W: usize = 2;
/// Supersteps driven per schedule.
const EPOCHS: u64 = 2;
/// Messages per (sender, shard) row per epoch.
const MSGS: u64 = 2;

fn encode(from: usize, epoch: u64, i: u64) -> u64 {
    ((from as u64) << 32) | (epoch << 8) | i
}

/// Replica of the FlatBoard + counting-gate kernel: plain message rows
/// handed off by per-row seal epochs, one counting gate per superstep.
struct MiniBoard {
    /// Message row per `(from, to)` pair — plain memory, protocol-ordered.
    rows: Vec<UnsafeCell<Vec<u64>>>,
    /// Monotone per-row seal epochs (release-published by the sender).
    seals: Vec<AtomicU64>,
    /// One counting gate per epoch: arrivals; the last arriver closes out.
    gates: Vec<AtomicU64>,
    /// Per-epoch delivered-message totals for the close-out assertion.
    delivered: Vec<AtomicU64>,
}

// SAFETY: the raw rows are `UnsafeCell`s whose cross-thread access is the
// protocol under test; every access is trace-checked by the model.
unsafe impl Sync for MiniBoard {}

impl MiniBoard {
    fn new() -> MiniBoard {
        MiniBoard {
            rows: (0..W * W).map(|_| UnsafeCell::new(Vec::new())).collect(),
            seals: (0..W * W).map(|_| AtomicU64::new(0)).collect(),
            gates: (0..=EPOCHS as usize).map(|_| AtomicU64::new(0)).collect(),
            delivered: (0..=EPOCHS as usize).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// One full run of the replica protocol: `W` workers × `EPOCHS` supersteps,
/// each epoch = fill own rows → seal (with `seal_order`) → drain every
/// sender's row for our shard → counting-gate close-out. `seal_order` is
/// `Release` for the real protocol and `Relaxed` for the injected-bug test.
fn seal_drain_protocol(sess: &Arc<Session>, seal_order: Ordering) {
    let board = MiniBoard::new();
    std::thread::scope(|scope| {
        for w in 0..W {
            let board = &board;
            scope.spawn(move || {
                let _reg = sess.register(w);
                for e in 1..=EPOCHS {
                    // Send phase: refill and seal this worker's rows.
                    for to in 0..W {
                        let row = &board.rows[w * W + to];
                        trace_write(row.get() as usize);
                        // SAFETY: worker `w` is the only writer of its rows
                        // this phase; reuse is gated by the previous epoch's
                        // counting gate (the property being checked).
                        let cell = unsafe { &mut *row.get() };
                        cell.clear();
                        for i in 0..MSGS {
                            cell.push(encode(w, e, i));
                        }
                        board.seals[w * W + to].store(e, seal_order);
                    }
                    // Drain phase: wait for each sender's seal, then read.
                    let mut got = Vec::new();
                    for from in 0..W {
                        loop {
                            let s = board.seals[from * W + w].load(Ordering::Acquire);
                            // Seal epochs are monotone and never run ahead:
                            // epoch e+1 seals only happen after gate e.
                            assert!(s <= e, "seal epoch ran ahead: {s} > {e}");
                            if s == e {
                                break;
                            }
                        }
                        let row = &board.rows[from * W + w];
                        trace_read(row.get() as usize);
                        // SAFETY: the observed seal means `from` finished
                        // this row for epoch `e` (release/acquire pair).
                        let cell = unsafe { &*row.get() };
                        got.extend_from_slice(cell);
                    }
                    // Exactly every sent message arrives, once.
                    let mut expect: Vec<u64> = (0..W)
                        .flat_map(|f| (0..MSGS).map(move |i| encode(f, e, i)))
                        .collect();
                    got.sort_unstable();
                    expect.sort_unstable();
                    assert_eq!(got, expect, "lost/duplicated messages in epoch {e}");
                    board.delivered[e as usize].fetch_add(got.len() as u64, Ordering::AcqRel);
                    // Counting gate: the last arriver closes the epoch out.
                    let before = board.gates[e as usize].fetch_add(1, Ordering::AcqRel);
                    if before + 1 == W as u64 {
                        let total = board.delivered[e as usize].load(Ordering::Acquire);
                        assert_eq!(total, (W * W) as u64 * MSGS, "close-out at epoch {e}");
                    }
                    // Step gate: nobody refills rows before everyone drained.
                    while board.gates[e as usize].load(Ordering::Acquire) < W as u64 {}
                }
            });
        }
    });
}

/// Tier-1 smoke: the correctly-ordered protocol survives well over a
/// thousand distinct schedules with no race, no lost message, and no
/// gate/seal violation.
#[test]
fn seal_drain_and_gates_survive_many_schedules() {
    let report = Explorer::new(W)
        .schedules(1200)
        .seed(0xC0FFEE)
        .run(|sess| seal_drain_protocol(sess, Ordering::Release));
    report.assert_clean();
    assert!(
        report.distinct_schedules >= 1000,
        "only {} distinct schedules explored",
        report.distinct_schedules
    );
}

/// Injected-bug detection: downgrading the seal store to `Relaxed` removes
/// the happens-before edge that makes row reuse sound, and the checker's
/// vector clocks must call the resulting plain-memory race out.
#[test]
fn relaxed_seal_is_detected_as_race() {
    let report = Explorer::new(W)
        .schedules(60)
        .seed(0xBAD5EED)
        .budget(30_000)
        .run(|sess| seal_drain_protocol(sess, Ordering::Relaxed));
    assert!(
        !report.failures.is_empty(),
        "relaxed seal went undetected across {} schedules",
        report.schedules_run
    );
    assert!(
        report.failures.iter().any(|f| f.contains("data race")),
        "expected a data-race report, got: {:?}",
        report.failures.first()
    );
}

/// A spin-free message-pass fits in the bounded-exhaustive mode: the DFS
/// enumerates the complete schedule tree and proves the release/acquire
/// publication for *every* interleaving, not a sample.
#[test]
fn exhaustive_message_pass_is_complete() {
    struct RacyCell(UnsafeCell<u64>);
    // SAFETY: cross-thread access is ordered by the flag release/acquire
    // pair and checked by the model's trace hooks.
    unsafe impl Sync for RacyCell {}

    let report = Explorer::new(2).schedules(10_000).exhaustive().run(|sess| {
        let data = RacyCell(UnsafeCell::new(0));
        let flag = AtomicU64::new(0);
        std::thread::scope(|scope| {
            let (d, f) = (&data, &flag);
            scope.spawn(move || {
                let _reg = sess.register(0);
                trace_write(d.0.get() as usize);
                // SAFETY: published to the reader by the release store.
                unsafe { *d.0.get() = 42 };
                f.store(1, Ordering::Release);
            });
            scope.spawn(move || {
                let _reg = sess.register(1);
                if f.load(Ordering::Acquire) == 1 {
                    trace_read(d.0.get() as usize);
                    // SAFETY: the acquire load saw the writer's release.
                    assert_eq!(unsafe { *d.0.get() }, 42);
                }
            });
        });
    });
    report.assert_clean();
    assert!(report.complete, "exhaustive run did not drain the tree");
    assert!(report.distinct_schedules >= 2);
}

/// Replica of the superstep runtime's cancel-vs-convergence bookkeep
/// (`engine/superstep.rs`): per epoch, a counting gate elects a closer;
/// the closer decides the terminal outcome — natural convergence wins
/// over a concurrent cancel, a cancel otherwise goes terminal at the
/// next gate — and publishes `step_done` exactly once. A third thread
/// raises the cancel token at a model-explored point. The invariants:
/// the terminal transition is taken exactly once (the CAS from `none`
/// never loses), `step_done` is never double-published, and every run
/// ends terminal — a cancel can never wedge the runtime.
mod cancel_bookkeep {
    use super::*;

    /// Workers arriving at each epoch's counting gate.
    const CW: usize = 2;
    /// Epoch at which activity naturally drains to zero (convergence).
    const CEPOCHS: u64 = 3;

    const NONE: u64 = 0;
    const CONVERGED: u64 = 1;
    const CANCELLED: u64 = 2;

    struct CancelKernel {
        /// Raised once by the canceller thread (release store).
        cancel: AtomicU64,
        /// Runner loop-exit flag, set by the deciding closer.
        stop: AtomicU64,
        /// Terminal outcome cell: single CAS winner from `NONE`.
        terminal: AtomicU64,
        /// Counting gate per epoch; the last arriver closes out.
        arrivals: Vec<AtomicU64>,
        /// `step_done` publication cell per epoch (must stay ≤ 1).
        step_done: Vec<AtomicU64>,
    }

    impl CancelKernel {
        fn new() -> CancelKernel {
            CancelKernel {
                cancel: AtomicU64::new(0),
                stop: AtomicU64::new(0),
                terminal: AtomicU64::new(NONE),
                arrivals: (0..=CEPOCHS as usize).map(|_| AtomicU64::new(0)).collect(),
                step_done: (0..=CEPOCHS as usize).map(|_| AtomicU64::new(0)).collect(),
            }
        }

        /// The bookkeep decision, exactly as the runtime orders it:
        /// natural convergence first, then a pending cancel.
        fn decide(&self, outcome: u64, epoch: u64) {
            let won = self
                .terminal
                .compare_exchange(NONE, outcome, Ordering::AcqRel, Ordering::Acquire);
            assert!(
                won.is_ok(),
                "second terminal transition at epoch {epoch}: {outcome} after {:?}",
                won
            );
            self.stop.store(1, Ordering::Release);
        }
    }

    fn cancel_vs_convergence(sess: &Arc<Session>) {
        let k = CancelKernel::new();
        std::thread::scope(|scope| {
            let k = &k;
            // The canceller: one release store, landing anywhere the
            // explorer puts it relative to the workers' gates.
            scope.spawn(move || {
                let _reg = sess.register(CW);
                k.cancel.store(1, Ordering::Release);
            });
            for w in 0..CW {
                scope.spawn(move || {
                    let _reg = sess.register(w);
                    for e in 1..=CEPOCHS {
                        let before = k.arrivals[e as usize].fetch_add(1, Ordering::AcqRel);
                        if before + 1 == CW as u64 {
                            // Closer: decide, then publish the step gate.
                            let active = CEPOCHS - e;
                            if active == 0 {
                                k.decide(CONVERGED, e);
                            } else if k.cancel.load(Ordering::Acquire) == 1 {
                                k.decide(CANCELLED, e);
                            }
                            let prev =
                                k.step_done[e as usize].fetch_add(1, Ordering::AcqRel);
                            assert_eq!(prev, 0, "step_done double-published at epoch {e}");
                        } else {
                            // Non-closer: park on the epoch's step gate.
                            while k.step_done[e as usize].load(Ordering::Acquire) == 0 {}
                        }
                        // The closer's stop store happens-before the
                        // publication every worker just acquired, so all
                        // workers exit at the same epoch.
                        if k.stop.load(Ordering::Acquire) == 1 {
                            break;
                        }
                    }
                });
            }
        });
        // Terminal exactly once, never lost: the canceller always fires,
        // and epoch CEPOCHS converges, so every schedule ends terminal.
        let t = k.terminal.load(Ordering::Acquire);
        assert!(
            t == CONVERGED || t == CANCELLED,
            "run ended non-terminal (terminal = {t})"
        );
        assert_eq!(k.stop.load(Ordering::Acquire), 1, "stop flag lost");
        // Step gates publish once per run epoch and stop contiguously at
        // the terminal epoch — no gate after the decision, none skipped
        // before it.
        let published: Vec<u64> = (1..=CEPOCHS as usize)
            .map(|e| k.step_done[e].load(Ordering::Acquire))
            .collect();
        assert!(published.iter().all(|&p| p <= 1), "{published:?}");
        assert!(published[0] == 1, "epoch 1 must always close: {published:?}");
        for pair in published.windows(2) {
            assert!(
                !(pair[0] == 0 && pair[1] == 1),
                "gate published after a skipped epoch: {published:?}"
            );
        }
    }

    /// ≥1,000 distinct schedules of cancel racing natural convergence:
    /// no lost terminal transition, no double-published step gate.
    #[test]
    fn cancel_vs_convergence_keeps_exactly_one_terminal_transition() {
        let report = Explorer::new(CW + 1)
            .schedules(1200)
            .seed(0xCA11CE1)
            .run(|sess| cancel_vs_convergence(sess));
        report.assert_clean();
        assert!(
            report.distinct_schedules >= 1000,
            "only {} distinct schedules explored",
            report.distinct_schedules
        );
    }
}

/// With `--cfg unigps_model` the facade swaps the *real* kernel onto the
/// model types — drive the actual [`FlatBoard`] seal/drain handoff through
/// the checker rather than a replica.
#[cfg(unigps_model)]
mod real_kernel {
    use super::*;
    use unigps::distributed::comm::FlatBoard;

    #[test]
    fn flatboard_seal_drain_under_model() {
        let report = Explorer::new(2).schedules(300).seed(31).run(|sess| {
            let board: FlatBoard<u64> = FlatBoard::new(2);
            std::thread::scope(|scope| {
                for w in 0..2usize {
                    let board = &board;
                    scope.spawn(move || {
                        let _reg = sess.register(w);
                        for e in 1..=2u64 {
                            let parity = (e & 1) as u32;
                            for to in 0..2 {
                                // SAFETY: worker `w` is the exclusive sender
                                // of its rows; parity alternation keeps the
                                // two in-flight epochs on disjoint cells.
                                unsafe {
                                    board.push(parity, w, to, to as u32, encode(w, e, 0));
                                }
                                board.seal_row(w, to, e);
                            }
                            let mut got = Vec::new();
                            for from in 0..2 {
                                while board.sealed_epoch(from, w) < e {}
                                // SAFETY: the acquire-loaded seal above is
                                // exactly the drain precondition.
                                unsafe {
                                    board.drain_from(parity, from, w, |_dst, m| got.push(m));
                                }
                            }
                            got.sort_unstable();
                            assert_eq!(got, vec![encode(0, e, 0), encode(1, e, 0)]);
                        }
                    });
                }
            });
        });
        report.assert_clean();
        assert!(report.distinct_schedules > 100);
    }
}
