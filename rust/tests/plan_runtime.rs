//! Integration: the logical-plan IR end to end — plan execution is
//! bit-identical to the equivalent sequence of manual `run_operator`
//! calls on 30 random graphs under every partition strategy with the
//! superstep pipeline on and off; every single-op surface (fluent
//! builder, session methods, flat job specs) lowers to the same `Plan`
//! value; and the text/wire codecs round-trip the IR exactly.

use unigps::config::Config;
use unigps::engine::{EngineKind, RunOptions, RunResult};
use unigps::graph::generate;
use unigps::graph::partition::PartitionStrategy;
use unigps::operators::{run_operator, Operator, OperatorBuilder};
use unigps::plan::{Cmp, JoinItem, Plan, PostOp, Pred, Stage, Transform};
use unigps::serve::jobs::JobSpec;
use unigps::session::Session;
use unigps::util::propcheck::{forall, Config as PropConfig};
use unigps::vcprog::Column;

const ALL_STRATEGIES: [PartitionStrategy; 3] = [
    PartitionStrategy::Hash,
    PartitionStrategy::Range,
    PartitionStrategy::EdgeBalanced,
];

fn bits_equal(a: &RunResult, b: &RunResult) -> bool {
    a.columns.len() == b.columns.len()
        && a.columns.iter().zip(&b.columns).all(|((an, ac), (bn, bc))| {
            an == bn
                && match (ac, bc) {
                    (Column::I64(x), Column::I64(y)) => x == y,
                    (Column::F64(x), Column::F64(y)) => {
                        x.len() == y.len()
                            && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                    }
                    _ => false,
                }
        })
}

/// Property: a 3-stage plan (symmetrize → cc → kcore → sssp, mixed
/// engines) produces stage tables bit-identical to the manual
/// `run_operator` sequence with the same options — under every partition
/// strategy, with the overlapped superstep pipeline on and off.
#[test]
fn plan_matches_manual_operator_sequence_on_30_random_graphs() {
    forall(
        PropConfig::new(30, 0x9A17),
        |rng| {
            let n = 4 + rng.usize_below(96);
            let m = n * (1 + rng.usize_below(5));
            let workers = 1 + rng.usize_below(4);
            let k = 1 + rng.usize_below(4) as i64;
            (generate::random_for_tests(n, m, rng.next_u64()), workers, k)
        },
        |(g, workers, k)| {
            let stages: [(Operator, EngineKind); 3] = [
                (Operator::ConnectedComponents, EngineKind::Gas),
                (Operator::KCore { k: *k }, EngineKind::Pregel),
                (Operator::Sssp { root: 0 }, EngineKind::PushPull),
            ];
            // After the explicit symmetrize transform, *every* stage runs
            // on the undirected view — including sssp, whose manual
            // ground truth therefore also takes the symmetrized graph
            // (for cc/kcore, `run_operator`'s op-local symmetrize is
            // idempotent on it).
            let sym = unigps::operators::symmetrized(g);
            for strategy in ALL_STRATEGIES {
                for pipeline in [true, false] {
                    let mut opts = RunOptions::default().with_workers(*workers);
                    opts.partition = strategy;
                    opts.pipeline = pipeline;

                    let mut plan = Plan::new()
                        .default_key("workers", workers)
                        .default_key("partition", strategy.name())
                        .default_key("pipeline", pipeline)
                        .transform(Transform::Symmetrize);
                    for (op, engine) in &stages {
                        plan = plan.stage(Stage::op(op.clone()).engine(*engine));
                    }
                    let out = plan
                        .run_on_detailed(g, &Session::builder().build())
                        .map_err(|e| e.to_string())?;

                    for (i, (op, engine)) in stages.iter().enumerate() {
                        let manual = run_operator(&sym, op, *engine, &opts)
                            .map_err(|e| e.to_string())?;
                        if !bits_equal(&out.stages[i], &manual) {
                            return Err(format!(
                                "stage {i} ({}) diverged from run_operator \
                                 (w={workers}, {strategy:?}, pipeline={pipeline})",
                                op.name()
                            ));
                        }
                    }
                    // No post-ops: the final table is the last stage's.
                    if out.result.columns != out.stages[2].columns {
                        return Err("final table != last stage table".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// Property: the symmetrize transform is exactly the per-op symmetrize —
/// a plan running sssp (directed semantics) *after* an explicit
/// symmetrize matches `run_operator` on the symmetrized graph.
#[test]
fn explicit_symmetrize_matches_op_local_symmetrize_on_30_random_graphs() {
    forall(
        PropConfig::new(30, 0xC0DE),
        |rng| {
            let n = 4 + rng.usize_below(80);
            let m = n * (1 + rng.usize_below(4));
            (generate::random_for_tests(n, m, rng.next_u64()),)
        },
        |(g,)| {
            let session = Session::builder().workers(2).build();
            let plan = Plan::new()
                .transform(Transform::Symmetrize)
                .stage(Stage::op(Operator::Sssp { root: 0 }));
            let via_plan = plan.run_on(g, &session).map_err(|e| e.to_string())?;
            let sym = unigps::operators::symmetrized(g);
            let manual = run_operator(
                &sym,
                &Operator::Sssp { root: 0 },
                EngineKind::Pregel,
                session.options(),
            )
            .map_err(|e| e.to_string())?;
            if !bits_equal(&via_plan, &manual) {
                return Err("sssp on explicit symmetrized view diverged".into());
            }
            Ok(())
        },
    );
}

/// Acceptance: the fluent builder, the session convenience methods and
/// the flat job-spec form all lower to the same `Plan` IR value.
#[test]
fn every_single_op_surface_lowers_to_the_same_plan() {
    let g = generate::random_for_tests(32, 64, 5);

    // Surface 1: the fluent builder.
    let from_builder = OperatorBuilder::new(&g, Operator::Sssp { root: 5 })
        .engine(EngineKind::Gas)
        .workers(3)
        .to_plan();

    // Surface 2: the session convenience method (same explicit overrides).
    let session = Session::builder().build();
    let from_session = session.sssp(&g, 5).engine(EngineKind::Gas).workers(3).to_plan();

    // Surface 3: the flat serve job-spec text (plus a source, which the
    // in-process surfaces don't carry — they hold the graph itself).
    let spec = JobSpec::parse(
        "algo = sssp\nroot = 5\nengine = gas\nworkers = 3\n\
         kind = rmat\nvertices = 64\nedges = 128\nseed = 9",
        &Session::builder().build(),
    )
    .unwrap();
    let mut from_spec = spec.plan.clone();
    from_spec.source = None;

    // Surface 4: hand-built IR.
    let mut overrides = Config::new();
    overrides.set("engine", "gas");
    overrides.set("workers", "3");
    let by_hand = Plan::new().stage(Stage {
        op: unigps::plan::StageOp::Op(Operator::Sssp { root: 5 }),
        overrides,
    });

    assert_eq!(from_builder, from_session, "builder == session method");
    assert_eq!(from_builder, from_spec, "builder == parsed job spec");
    assert_eq!(from_builder, by_hand, "builder == hand-built IR");

    // And the lowered plan actually runs identically on every surface.
    let via_builder = OperatorBuilder::new(&g, Operator::Sssp { root: 5 })
        .engine(EngineKind::Gas)
        .workers(3)
        .run()
        .unwrap();
    let via_plan = by_hand.run_on(&g, &Session::builder().build()).unwrap();
    assert!(bits_equal(&via_builder, &via_plan));
}

/// The full fraud-style pipeline round-trips through both codecs and
/// executes identically before and after each round trip.
#[test]
fn pipeline_roundtrips_through_text_and_wire_and_still_runs() {
    let g = generate::random_for_tests(256, 2048, 77);
    let plan = Plan::new()
        .default_key("workers", 2)
        .transform(Transform::Symmetrize)
        .stage(Stage::op(Operator::KCore { k: 2 }))
        .transform(Transform::SubgraphByColumn {
            stage: 0,
            column: "in_core".into(),
            pred: Pred { cmp: Cmp::Eq, value: 1.0 },
        })
        .stage(Stage::op(Operator::Lpa { iterations: 6 }).engine(EngineKind::Gas))
        .post(PostOp::JoinColumns {
            items: vec![
                JoinItem { stage: 0, column: "in_core".into(), rename: None },
                JoinItem { stage: 1, column: "community".into(), rename: Some("ring".into()) },
            ],
        });

    let via_text = Plan::parse_text(&plan.to_text()).unwrap();
    assert_eq!(plan, via_text);
    let via_wire = unigps::plan::wire::decode_plan(&unigps::plan::wire::encode_plan(&plan)).unwrap();
    assert_eq!(plan, via_wire);

    let session = Session::builder().workers(2).build();
    let a = plan.run_on(&g, &session).unwrap();
    let b = via_text.run_on(&g, &session).unwrap();
    let c = via_wire.run_on(&g, &session).unwrap();
    assert!(bits_equal(&a, &b), "text round trip changed results");
    assert!(bits_equal(&a, &c), "wire round trip changed results");

    // Join semantics: rows only for core vertices, labeled by LPA on the
    // core subgraph, keyed by original vertex id.
    let vertex = a.column("vertex").unwrap().as_i64().unwrap();
    let in_core = a.column("in_core").unwrap().as_i64().unwrap();
    assert!(!vertex.is_empty());
    assert!(vertex.windows(2).all(|w| w[0] < w[1]), "ids ascend");
    assert!(in_core.iter().all(|&c| c == 1), "only core rows survive the join");
    assert!(a.column("ring").is_some());
}

/// Derived-variant memoization in the in-process path: a plan with an
/// explicit symmetrize and three undirected-semantics stages symmetrizes
/// once (the executor memoizes variants per execution).
#[test]
fn in_process_plan_symmetrizes_once_for_many_stages() {
    let g = generate::random_for_tests(128, 512, 11);
    let session = Session::builder().workers(2).build();
    let plan = Plan::new()
        .transform(Transform::Symmetrize)
        .stage(Stage::op(Operator::ConnectedComponents))
        .stage(Stage::op(Operator::KCore { k: 2 }))
        .stage(Stage::op(Operator::Triangles));
    let out = plan.run_on_detailed(&g, &session).unwrap();
    assert_eq!(out.stages.len(), 3);
    // Cross-check each stage against the historical per-op path.
    let opts = session.options();
    for (i, op) in [
        Operator::ConnectedComponents,
        Operator::KCore { k: 2 },
        Operator::Triangles,
    ]
    .iter()
    .enumerate()
    {
        let manual = run_operator(&g, op, EngineKind::Pregel, opts).unwrap();
        assert!(bits_equal(&out.stages[i], &manual), "stage {i} diverged");
    }
    // Aggregated metrics cover all stages.
    let total: u32 = out.stages.iter().map(|s| s.metrics.supersteps).sum();
    assert_eq!(out.result.metrics.supersteps, total);
}
