//! Integration: the `unigps serve` subsystem end to end — one server
//! thread, concurrent client threads, mixed operators and multi-stage
//! plans against one dataset spec. Checks the serving guarantees:
//! results are bit-identical to direct `engine::run` calls with the same
//! options, the snapshot cache loads the base graph exactly once
//! (dataset-level hit counter = requests − 1) and derives shared
//! variants exactly once (derived-level counters), the admission queue
//! rejects overload with a typed backpressure error instead of buffering
//! it, ERR frames carry the error kind end to end, and cooperative
//! cancellation (client cancel and `deadline_ms` watchdog) drives a
//! running job terminal within about one superstep, waking parked
//! waiters and freeing the slot. The `METRICS` snapshot fetched over the
//! wire matches in-process registry reads (same series, sandwiched
//! values, bit-identical codec round trip). An ingest leg drives the
//! evolving-dataset path end to end: delta batches over the wire,
//! generation-keyed caching, epoch pins (`docs/evolving.md`).
//!
//! Every test drives the unified [`Client`] trait, and the transport is
//! an environment matrix: `UNIGPS_TEST_TRANSPORT=uds` (default) runs the
//! suite over the Unix-domain socket, `=tcp` over the token-authenticated
//! TCP listener — same assertions, so the two transports are proven
//! interchangeable (CI runs both).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};
use unigps::client::Client;
use unigps::delta::DeltaBatch;
use unigps::engine::{EngineKind, RunOptions, RunResult};
use unigps::error::UniGpsError;
use unigps::ipc::shm::ShmMap;
use unigps::operators::{run_operator, Operator};
use unigps::plan::{DatasetRef, Plan, Stage, Transform};
use unigps::serve::{JobState, RemoteClient, ServeClient, ServeConfig, Server};
use unigps::session::Session;
use unigps::vcprog::Column;

/// The one dataset spec every job in these tests shares.
const VERTICES: usize = 512;
const EDGES: usize = 2048;
const SEED: u64 = 909;
const JOB_WORKERS: usize = 2;

fn dataset_spec_lines() -> String {
    format!("kind = rmat\nvertices = {VERTICES}\nedges = {EDGES}\nseed = {SEED}\nworkers = {JOB_WORKERS}")
}

/// The graph every spec above resolves to (seeded, so byte-deterministic).
fn dataset_graph() -> unigps::graph::Graph {
    Session::builder().build().generate("rmat", VERTICES, EDGES, SEED)
}

/// (spec suffix, operator, engine) for the mixed workload. Engines vary so
/// the scheduler demonstrably runs heterogeneous backends concurrently.
fn workload() -> Vec<(String, Operator, EngineKind)> {
    vec![
        (
            "algo = pagerank\niterations = 5\nengine = pregel".into(),
            Operator::PageRank { iterations: 5 },
            EngineKind::Pregel,
        ),
        (
            "algo = sssp\nroot = 0\nengine = pushpull".into(),
            Operator::Sssp { root: 0 },
            EngineKind::PushPull,
        ),
        (
            "algo = cc\nengine = gas".into(),
            Operator::ConnectedComponents,
            EngineKind::Gas,
        ),
    ]
}

/// The exact options the scheduler derives for these specs: requested
/// workers (2) ≤ per-slot share, everything else serving defaults.
fn job_options() -> RunOptions {
    RunOptions::default().with_workers(JOB_WORKERS)
}

fn columns_bit_identical(a: &RunResult, b: &RunResult) -> bool {
    a.columns.len() == b.columns.len()
        && a.columns.iter().zip(&b.columns).all(|((an, ac), (bn, bc))| {
            an == bn
                && match (ac, bc) {
                    (Column::I64(x), Column::I64(y)) => x == y,
                    (Column::F64(x), Column::F64(y)) => {
                        x.len() == y.len()
                            && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                    }
                    _ => false,
                }
        })
}

/// Preshared token the TCP matrix leg authenticates with.
const TEST_TOKEN: &str = "serve-integration-token";

/// The transport under test: `UNIGPS_TEST_TRANSPORT=uds|tcp`, default uds.
fn test_transport() -> String {
    std::env::var("UNIGPS_TEST_TRANSPORT").unwrap_or_else(|_| "uds".into())
}

/// A running server plus the endpoint the matrix leg connects to.
struct TestServe {
    socket: PathBuf,
    tcp_addr: Option<std::net::SocketAddr>,
    handle: std::thread::JoinHandle<()>,
}

impl TestServe {
    /// A fresh [`Client`] for the transport under test. Boxed — the
    /// tests are written against the trait, exactly like the CLI.
    fn client(&self) -> Box<dyn Client> {
        match self.tcp_addr {
            Some(addr) => Box::new(
                RemoteClient::connect_tcp(&addr.to_string(), TEST_TOKEN)
                    .expect("tcp connect + hello"),
            ),
            None => Box::new(ServeClient::connect(&self.socket).expect("uds connect")),
        }
    }

    fn join(self) {
        self.handle.join().expect("server thread");
    }
}

fn start_server(mut cfg: ServeConfig) -> TestServe {
    let transport = test_transport();
    if transport == "tcp" {
        cfg.tcp = Some("127.0.0.1:0".into());
        cfg.token = Some(TEST_TOKEN.into());
    } else {
        assert_eq!(transport, "uds", "UNIGPS_TEST_TRANSPORT must be uds or tcp");
    }
    let socket = cfg.socket.clone();
    let server = Server::bind(Session::builder().build(), cfg).expect("bind serve listeners");
    let tcp_addr = server.tcp_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve loop"));
    TestServe {
        socket,
        tcp_addr,
        handle,
    }
}

/// ≥4 concurrent clients submit mixed pagerank/sssp/cc jobs against the
/// same dataset spec; every result is bit-identical to a direct
/// `engine::run` with the scheduler's options, the snapshot cache reports
/// exactly one base load with dataset hit counter = jobs − 1, and the cc
/// jobs' shared symmetrized view derives exactly once (derived-level
/// counters, so the dataset accounting keeps its historical meaning).
#[test]
fn concurrent_mixed_jobs_share_one_snapshot_and_match_direct_runs() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-int"));
    cfg.slots = 2;
    cfg.queue_cap = 64;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 4; // split 2 ways -> 2 workers per job
    assert_eq!(cfg.per_job_workers(), JOB_WORKERS);
    let server = start_server(cfg);

    // Ground truth: direct engine::run dispatch on the same graph with the
    // same options the scheduler derives.
    let graph = dataset_graph();
    let opts = job_options();
    let expected: Vec<RunResult> = workload()
        .iter()
        .map(|(_, op, engine)| run_operator(&graph, op, *engine, &opts).unwrap())
        .collect();
    let expected = Arc::new(expected);

    let clients: usize = 4;
    let jobs_per_client: usize = 3; // 12 jobs total, all three operators each
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            let expected = expected.clone();
            s.spawn(move || {
                let mut client = server.client();
                for j in 0..jobs_per_client {
                    let which = (c + j) % expected.len();
                    let spec =
                        format!("{}\n{}", dataset_spec_lines(), workload()[which].0);
                    let id = client.submit(&spec).expect("submit");
                    let got = client
                        .wait(id, Duration::from_secs(120))
                        .expect("job finishes");
                    assert!(
                        columns_bit_identical(&got, &expected[which]),
                        "client {c} job {j} (workload {which}) diverged from direct run"
                    );
                    assert!(got.metrics.supersteps > 0);
                }
            });
        }
    });

    // Cache accounting: 12 jobs over one (dataset, partition) key; the 4
    // cc jobs share one derived (symmetrized) snapshot.
    let mut client = server.client();
    let stats = client.stats().expect("stats");
    let total_jobs = (clients * jobs_per_client) as u64;
    let cc_jobs = total_jobs / 3;
    assert_eq!(stats.jobs.completed, total_jobs, "all jobs completed");
    assert_eq!(stats.jobs.failed, 0);
    assert_eq!(stats.cache.loads, 1, "exactly one base snapshot load");
    assert_eq!(stats.cache.misses, 1);
    assert_eq!(
        stats.cache.hits,
        total_jobs - 1,
        "dataset hit counter = jobs - 1 (every job after the first shares the snapshot)"
    );
    assert_eq!(stats.cache.derived_loads, 1, "one symmetrize for all cc jobs");
    assert_eq!(stats.cache.derived_misses, 1);
    assert_eq!(
        stats.cache.derived_hits,
        cc_jobs - 1,
        "every cc job after the first shares the symmetrized snapshot"
    );
    assert_eq!(stats.cache.resident, 2, "base + symmetrized variant resident");

    client.shutdown().expect("shutdown");
    drop(client);
    let socket = server.socket.clone();
    server.join();
    assert!(!socket.exists(), "socket file removed on shutdown");
}

/// The acceptance pipeline: a 3-stage plan (symmetrize → cc → kcore)
/// submitted by N concurrent clients — half as sectioned text, half over
/// the binary plan codec — performs exactly one base snapshot load and
/// one symmetrize, every stage result bit-identical to the manual
/// `run_operator` sequence with the same options.
#[test]
fn three_stage_plan_shares_one_base_load_and_one_derive() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-plan"));
    cfg.slots = 2;
    cfg.queue_cap = 64;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 4;
    let server = start_server(cfg);

    let plan_text = format!(
        "{}\n\n[transform]\nop = symmetrize\n\n\
         [stage]\nalgo = cc\nengine = gas\n\n\
         [stage]\nalgo = kcore\nk = 3\n",
        dataset_spec_lines()
    );
    let plan = Plan::parse_text(&plan_text).expect("plan parses");

    // Ground truth: the manual call sequence the plan replaces. The final
    // table of a post-op-free plan is the last stage's (kcore) table.
    let graph = dataset_graph();
    let opts = job_options();
    let expected_kcore = run_operator(
        &graph,
        &Operator::KCore { k: 3 },
        EngineKind::Pregel,
        &opts,
    )
    .unwrap();

    let clients: usize = 4;
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            let plan = &plan;
            let plan_text = &plan_text;
            let expected = &expected_kcore;
            s.spawn(move || {
                let mut client = server.client();
                // Half the clients exercise the text path, half the wire
                // codec — both must land on the same executor.
                let id = if c % 2 == 0 {
                    client.submit(plan_text).expect("submit text plan")
                } else {
                    client.submit_plan(plan).expect("submit wire plan")
                };
                let got = client.wait(id, Duration::from_secs(120)).expect("plan job");
                assert!(
                    columns_bit_identical(&got, expected),
                    "client {c}: plan result diverged from manual kcore run"
                );
            });
        }
    });

    let mut client = server.client();
    let stats = client.stats().expect("stats");
    assert_eq!(stats.jobs.completed, clients as u64);
    assert_eq!(stats.jobs.failed, 0);
    assert_eq!(stats.cache.loads, 1, "one base load across {clients} plans");
    assert_eq!(stats.cache.derived_loads, 1, "one symmetrize across {clients} plans");
    assert_eq!(stats.cache.hits, clients as u64 - 1);
    assert_eq!(stats.cache.derived_hits, clients as u64 - 1);
    assert_eq!(stats.cache.resident, 2);

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}

/// `count` edge pairs absent from `g` (and distinct from each other) —
/// fodder for delta batches that are guaranteed to apply.
fn absent_pairs(g: &unigps::graph::Graph, count: usize) -> Vec<(u32, u32)> {
    let topo = g.topology();
    let n = topo.num_vertices() as u32;
    let mut out = Vec::new();
    'scan: for u in 0..n {
        for v in 0..n {
            if u != v && topo.out_edges(u).all(|(_, t)| t != v) {
                out.push((u, v));
                if out.len() == count {
                    break 'scan;
                }
            }
        }
    }
    assert_eq!(out.len(), count, "graph too dense for the fixture");
    out
}

/// The evolving-dataset acceptance path over both transports: a plan runs
/// on generation 0, [`Client::ingest`] applies a delta batch producing
/// generation 1, and a resubmit of the same plan re-derives its shared
/// variant exactly once against the new generation — while a
/// `generation = 0` pin keeps answering bit-identically from the
/// superseded snapshots (resident until evicted, never reloaded). The
/// `STATS` frame's trailing invalidation counter crosses the wire,
/// over-pins fail typed at run time, non-numeric pins at submit, and an
/// inapplicable batch leaves the generation chain untouched.
#[test]
fn ingest_advances_generations_and_pins_answer_from_old_snapshots() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-ingest"));
    cfg.slots = 2;
    cfg.queue_cap = 16;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 4;
    let server = start_server(cfg);

    let stages = "[transform]\nop = symmetrize\n\n[stage]\nalgo = pagerank\niterations = 5\n";
    let plan_text = format!("{}\n\n{stages}", dataset_spec_lines());
    let plan = Plan::parse_text(&plan_text).expect("plan parses");

    // The delta: three edges absent from generation 0 (computed against
    // the same seeded graph the server loads), plus a spare absent pair
    // kept aside so a later remove of it is guaranteed inapplicable.
    let parent = dataset_graph();
    let absent = absent_pairs(&parent, 4);
    let source = DatasetRef::Synthetic {
        kind: "rmat".into(),
        vertices: VERTICES,
        edges: EDGES,
        seed: SEED,
    };
    let adds: Vec<_> = absent[..3].iter().map(|&(u, v)| (u, v, 2.0)).collect();
    let batch = DeltaBatch::new(source.clone(), adds, vec![]).expect("valid batch");

    // Ground truths through the in-process executor: the plan on the
    // parent (generation 0) and on the locally applied child (generation
    // 1). Added out-edges change degrees, so the runs must diverge — the
    // pin assertions below would otherwise be vacuous.
    let session = Session::builder().workers(JOB_WORKERS).build();
    let gen0_truth = session.run_plan_on(&parent, &plan).expect("gen-0 run");
    let (child, removed) = batch.apply(&parent).expect("batch applies");
    assert_eq!(removed, 0);
    let gen1_truth = session.run_plan_on(&child, &plan).expect("gen-1 run");
    assert!(!columns_bit_identical(&gen0_truth, &gen1_truth));

    let mut client = server.client();
    // Generation 0: one base load, one symmetrize.
    let id = client.submit(&plan_text).expect("submit gen-0 plan");
    let got0 = client.wait(id, Duration::from_secs(120)).expect("gen-0 job");
    assert!(columns_bit_identical(&got0, &gen0_truth), "gen-0 serve run matches");
    let s = client.stats().expect("stats");
    assert_eq!((s.cache.loads, s.cache.derived_loads), (1, 1));
    assert_eq!(s.cache.invalidated, 0);

    // Ingest: epoch 1 committed; both resident generation-0 entries (base
    // + derived) are counted invalidated but stay resident.
    let receipt = client.ingest(&batch.to_text()).expect("ingest applies");
    assert_eq!(receipt.epoch, 1);
    assert_eq!(receipt.edges_added, 3);
    assert_eq!(receipt.edges_removed, 0);
    let s = client.stats().expect("stats");
    assert_eq!(s.cache.invalidated, 2, "gen-0 base + derived superseded");
    assert_eq!(s.cache.loads, 2, "the ingest made generation 1 resident");
    assert_eq!(s.cache.evictions, 0);

    // Resubmit: `latest` now resolves to generation 1 — the base snapshot
    // is already resident from the ingest, the symmetrized variant is
    // re-derived exactly once, and the result matches the child-graph run.
    let id = client.submit(&plan_text).expect("submit gen-1 plan");
    let got1 = client.wait(id, Duration::from_secs(120)).expect("gen-1 job");
    assert!(columns_bit_identical(&got1, &gen1_truth), "gen-1 serve run matches");
    assert!(!columns_bit_identical(&got1, &got0));
    let s = client.stats().expect("stats");
    assert_eq!(s.cache.derived_loads, 2, "re-derived exactly once");
    assert_eq!(s.cache.derived_hits, 0);
    assert_eq!(s.cache.loads, 2, "no extra base load for the resubmit");

    // A generation-0 pin keeps answering bit-identically from the
    // superseded snapshots — no new loads, no new derivations.
    let pinned_text = format!("{}\ngeneration = 0\n\n{stages}", dataset_spec_lines());
    let id = client.submit(&pinned_text).expect("submit pinned plan");
    let pinned = client.wait(id, Duration::from_secs(120)).expect("pinned job");
    assert!(columns_bit_identical(&pinned, &got0), "pin answers from generation 0");
    let s = client.stats().expect("stats");
    assert_eq!(s.cache.derived_loads, 2, "pinned run hit the old derived variant");
    assert_eq!(s.cache.derived_hits, 1);
    assert_eq!(s.cache.invalidated, 2, "reads never re-invalidate");
    assert_eq!(s.cache.resident, 4, "both generations, base + derived each");

    // Pinning an epoch the dataset never reached is a typed run-time
    // error (the pin may race a future ingest, so admission succeeds); a
    // non-numeric pin is rejected at submit.
    let over = format!("{}\nalgo = pagerank\ngeneration = 9", dataset_spec_lines());
    let id = client.submit(&over).expect("numeric over-pin admits");
    let err = client.wait(id, Duration::from_secs(60)).unwrap_err();
    assert!(err.to_string().contains("has no generation"), "{err}");
    let bad_pin = format!("{}\nalgo = pagerank\ngeneration = newest", dataset_spec_lines());
    let err = client.submit(&bad_pin).unwrap_err();
    assert!(matches!(err, UniGpsError::Config(_)), "{err:?}");

    // An inapplicable batch (remove of an absent edge) fails typed over
    // the wire and leaves the generation chain and the counters untouched.
    let bad = DeltaBatch::new(source, vec![], vec![absent[3]]).expect("well-formed batch");
    let err = client.ingest(&bad.to_text()).unwrap_err();
    assert!(matches!(err, UniGpsError::Config(_)), "{err:?}");
    assert!(err.to_string().contains("removes absent edge"), "{err}");
    let s = client.stats().expect("stats");
    assert_eq!(s.cache.invalidated, 2, "failed ingest invalidates nothing");

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}

/// Backpressure: with one slot and a two-deep queue, a burst of delayed
/// jobs must produce typed [`UniGpsError::Backpressure`] rejections —
/// reconstructed from the kind-tagged ERR frame, so clients match on the
/// kind, not message text — while every admitted job still completes and
/// is never silently dropped.
#[test]
fn queue_overload_is_rejected_with_a_typed_error() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-bp"));
    cfg.slots = 1;
    cfg.queue_cap = 2;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 2;
    let server = start_server(cfg);

    let mut client = server.client();
    // Each job sleeps 400ms before executing, so the single slot cannot
    // drain the burst: capacity is 1 running + 2 queued = 3 of 5.
    let spec = format!("{}\nalgo = sssp\ndelay_ms = 400", dataset_spec_lines());
    let mut admitted = Vec::new();
    let mut rejections = Vec::new();
    for _ in 0..5 {
        match client.submit(&spec) {
            Ok(id) => admitted.push(id),
            Err(e) => rejections.push(e),
        }
    }
    assert!(
        !rejections.is_empty(),
        "5 delayed submits into slots=1/queue=2 must overflow"
    );
    // The queue alone admits 2; whether the slot has already popped the
    // first job (admitting a 3rd) is a benign race.
    assert!(admitted.len() >= 2, "queue capacity admits at least 2");
    for r in &rejections {
        assert!(
            r.is_backpressure(),
            "typed backpressure crosses the wire, got: {r:?}"
        );
        assert!(matches!(r, UniGpsError::Backpressure(_)), "{r:?}");
        assert!(r.to_string().contains("queue full"), "{r}");
    }
    // A retrying submit eventually lands once the slot drains the burst.
    let id = client
        .submit_with_retry(&spec, Duration::from_secs(60))
        .expect("backpressure retry eventually admits");
    admitted.push(id);
    for id in &admitted {
        let result = client.wait(*id, Duration::from_secs(120));
        assert!(result.is_ok(), "admitted job {id} must complete: {result:?}");
    }
    let stats = client.stats().expect("stats");
    assert!(stats.jobs.rejected >= rejections.len() as u64);
    assert_eq!(stats.jobs.completed, admitted.len() as u64);

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}

/// Status/result error paths over the wire: unknown jobs, bad specs and
/// failed loads surface as typed server-side errors — the ERR kind tag
/// restores the exact [`UniGpsError`] variant — not hangs or garbage.
#[test]
fn wire_error_paths_are_clean_and_typed() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-err"));
    cfg.slots = 1;
    cfg.total_workers = 2;
    let server = start_server(cfg);

    let mut client = server.client();
    let err = client.status(424242).unwrap_err();
    assert!(matches!(err, UniGpsError::Serve(_)), "{err:?}");
    assert!(err.to_string().contains("unknown job"), "{err}");
    let err = client.result(424242).unwrap_err();
    assert!(matches!(err, UniGpsError::Serve(_)), "{err:?}");
    // A bad spec is rejected at submit time with the typed parse error.
    let err = client.submit("algo = astrology\nvertices = 64").unwrap_err();
    assert!(matches!(err, UniGpsError::Config(_)), "{err:?}");
    assert!(err.to_string().contains("unknown algo"), "{err}");
    // A forged wire plan fails typed too (no source).
    let err = client
        .submit_plan(&Plan::single(Operator::Degrees))
        .unwrap_err();
    assert!(matches!(err, UniGpsError::Config(_)), "{err:?}");
    // A job that fails at load time reports Failed + its typed error text.
    let id = client.submit("algo = cc\ndataset = atlantis").expect("admitted");
    let err = client.wait(id, Duration::from_secs(60)).unwrap_err();
    assert!(err.to_string().contains("unknown dataset"), "{err}");

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}

/// A plan with a filter + join post-op runs over serve and matches the
/// in-process plan executor bit for bit (same IR, same results, any
/// surface).
#[test]
fn pipeline_with_postops_matches_in_process_execution() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-post"));
    cfg.slots = 1;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 2;
    let server = start_server(cfg);

    let plan_text = format!(
        "{}\n\n[transform]\nop = symmetrize\n\n\
         [stage]\nalgo = kcore\nk = 3\n\n\
         [stage]\nalgo = lpa\niterations = 8\n\n\
         [post]\nop = join\ncolumns = 0:in_core, 1:community\n\n\
         [post]\nop = topk\ncolumn = in_core\nk = 16\n",
        dataset_spec_lines()
    );
    let plan = Plan::parse_text(&plan_text).expect("plan parses");
    // In-process ground truth through the very same IR value.
    let session = Session::builder().workers(JOB_WORKERS).build();
    let local = session.run_plan_on(&dataset_graph(), &plan).expect("local run");

    let mut client = server.client();
    let id = client.submit(&plan_text).expect("submit");
    let remote = client.wait(id, Duration::from_secs(120)).expect("job");
    assert!(
        columns_bit_identical(&remote, &local),
        "serve and in-process plan execution diverged"
    );
    assert_eq!(remote.columns[0].0, "vertex", "post-ops surface original ids");
    assert_eq!(remote.column("in_core").unwrap().len(), 16);

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();

    // The fluent builder path lowers to the same IR as text parsing.
    let built = Plan::new()
        .transform(Transform::Symmetrize)
        .stage(Stage::op(Operator::KCore { k: 3 }))
        .stage(Stage::op(Operator::Lpa { iterations: 8 }));
    let parsed = Plan::parse_text(
        "[transform]\nop = symmetrize\n\n[stage]\nalgo = kcore\nk = 3\n\n\
         [stage]\nalgo = lpa\niterations = 8\n",
    )
    .unwrap();
    assert_eq!(built.steps, parsed.steps, "one IR behind every surface");
}

/// The cancellation acceptance path over both transports: a running job
/// cancelled via [`Client::cancel`] reaches `Cancelled` within about one
/// superstep (not after its remaining minute of work), an observer
/// already parked in [`Client::wait`] is woken by the terminal
/// transition with the typed [`UniGpsError::Cancelled`] — the ERR kind
/// survives the wire — and the freed slot is immediately reused by the
/// next job.
#[test]
fn cancel_mid_run_goes_terminal_wakes_waiters_and_frees_the_slot() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-cancel"));
    cfg.slots = 1;
    cfg.queue_cap = 8;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 2;
    let server = start_server(cfg);

    let mut client = server.client();
    // Without the cancel this job would hold the only slot for 60 s — if
    // cancellation were lost, the waiter join below would blow its budget.
    let slow = format!("{}\nalgo = sssp\ndelay_ms = 60000", dataset_spec_lines());
    let slow_id = client.submit(&slow).expect("submit slow job");

    let (waiter_err, cancel_to_terminal) = std::thread::scope(|s| {
        // A second connection parks in wait() *before* the cancel lands;
        // it must be woken by the scheduler's completion broadcast.
        let waiter = s.spawn(|| {
            let mut c = server.client();
            c.wait(slow_id, Duration::from_secs(120))
                .expect_err("cancelled job must not yield a result")
        });
        // Let the job occupy the slot and the waiter park.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let st = client.status(slow_id).expect("status");
            if st.state == JobState::Running {
                break;
            }
            assert!(Instant::now() < deadline, "job never started: {st:?}");
            std::thread::sleep(Duration::from_millis(10));
        }
        std::thread::sleep(Duration::from_millis(50));

        let t0 = Instant::now();
        let st = client.cancel(slow_id).expect("cancel");
        // The status returned is as-of the cancel being applied: a running
        // job may legitimately still say Running; it must never be Done.
        assert_ne!(st.state, JobState::Done, "{st:?}");
        let err = client
            .wait(slow_id, Duration::from_secs(30))
            .expect_err("wait on a cancelled job is the typed error");
        let elapsed = t0.elapsed();
        assert!(err.is_cancelled(), "typed Cancelled crosses the wire: {err:?}");
        assert!(err.to_string().contains("client cancel"), "{err}");
        (waiter.join().expect("waiter thread"), elapsed)
    });
    assert!(
        waiter_err.is_cancelled(),
        "parked waiter woke with the typed error: {waiter_err:?}"
    );
    // Cancel-to-terminal latency: the 20 ms delay slices and the
    // per-superstep gate bound this to well under the job's 60 s.
    assert!(
        cancel_to_terminal < Duration::from_secs(10),
        "cancel took {cancel_to_terminal:?} to go terminal"
    );

    // Slot reuse: the next job runs to completion on the freed slot.
    let spec = format!("{}\nalgo = cc\nengine = gas", dataset_spec_lines());
    let id = client.submit(&spec).expect("submit follow-up");
    let got = client.wait(id, Duration::from_secs(120)).expect("slot reused");
    assert!(got.metrics.supersteps > 0);

    let stats = client.stats().expect("stats");
    assert_eq!(stats.jobs.cancelled, 1, "exactly the one cancelled job");
    assert_eq!(stats.jobs.completed, 1, "the follow-up job completed");
    assert_eq!(stats.jobs.failed, 0, "cancellation is not a failure");

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}

/// The METRICS surface end to end over the transport matrix (UDS or
/// TCP per `UNIGPS_TEST_TRANSPORT`): after a mixed workload, the
/// snapshot fetched over the wire exposes exactly the same series, in
/// the same registration order, as an in-process registry read; every
/// monotonic series is sandwiched between local reads taken around the
/// fetch (the registry is process-global, so other tests in this binary
/// feed it concurrently and exact equality would race); and the codec
/// round trip is bit-identical — re-encoding the decoded snapshot
/// reproduces the wire bytes exactly.
#[test]
fn metrics_round_trip_matches_in_process_registry_reads() {
    use unigps::obs::metrics::{snapshot, MetricsSnapshot};

    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-metrics"));
    cfg.slots = 2;
    cfg.queue_cap = 16;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 4;
    let server = start_server(cfg);

    // A small mixed workload so the registry demonstrably carries load.
    let mut client = server.client();
    for (suffix, _, _) in workload() {
        let spec = format!("{}\n{}", dataset_spec_lines(), suffix);
        let id = client.submit(&spec).expect("submit");
        client.wait(id, Duration::from_secs(120)).expect("job finishes");
    }

    let before = snapshot();
    let wire = client.metrics().expect("METRICS round trip");
    let after = snapshot();

    // Same series, same order: the snapshot is name-carrying, so a wire
    // read and a LocalClient read are interchangeable by construction.
    fn series(s: &MetricsSnapshot) -> Vec<&str> {
        s.counters
            .iter()
            .map(|(n, _)| n.as_str())
            .chain(s.gauges.iter().map(|(n, _)| n.as_str()))
            .chain(s.hists.iter().map(|(n, _)| n.as_str()))
            .collect()
    }
    assert_eq!(
        series(&wire),
        series(&before),
        "wire and in-process snapshots expose the same series"
    );

    // Sandwich every monotonic series: the server read the registry
    // between the two local reads, so before <= wire <= after.
    for (name, v) in &wire.counters {
        let b = before.counter(name).expect("counter known locally");
        let a = after.counter(name).expect("counter known locally");
        assert!(b <= *v && *v <= a, "{name}: sandwich {b} <= {v} <= {a} violated");
    }
    for (name, h) in &wire.hists {
        let b = before.hist(name).expect("hist known locally");
        let a = after.hist(name).expect("hist known locally");
        assert!(
            b.count <= h.count && h.count <= a.count,
            "{name}: count sandwich {} <= {} <= {} violated",
            b.count,
            h.count,
            a.count
        );
        assert!(b.sum_us <= h.sum_us && h.sum_us <= a.sum_us, "{name}: sum sandwich");
    }
    // Gauges are not monotonic, except uptime, which the in-process
    // server pinned at bind time.
    let up = "unigps_server_uptime_us";
    let (b, w, a) = (
        before.gauge(up).expect("uptime gauge"),
        wire.gauge(up).expect("uptime gauge"),
        after.gauge(up).expect("uptime gauge"),
    );
    assert!(b <= w && w <= a, "uptime sandwich {b} <= {w} <= {a} violated");
    assert!(a > 0, "an in-process Server::bind pins the uptime mark");

    // The workload above is visible in the wire snapshot: at-least
    // bounds, because the registry is shared with concurrent tests.
    let jobs = workload().len() as u64;
    assert!(wire.counter("unigps_jobs_submitted_total").unwrap() >= jobs);
    assert!(wire.counter("unigps_jobs_completed_total").unwrap() >= jobs);
    assert!(wire.counter("unigps_transport_connects_total").unwrap() >= 1);
    assert!(wire.counter("unigps_transport_bytes_read_total").unwrap() > 0);
    assert!(wire.counter("unigps_transport_bytes_written_total").unwrap() > 0);
    // Run time is milliseconds per job, so it always records; queue-wait
    // and per-step phases can legitimately round to 0 µs on an idle
    // server (zero observations are not recorded), so only presence is
    // asserted for them — which the series check above already did.
    assert!(wire.hist("unigps_sched_run_time_us").unwrap().count >= jobs);

    // Codec bit-identity: decode(encode(x)) re-encodes to the same bytes
    // the wire carried.
    let bytes = wire.encode();
    let decoded = MetricsSnapshot::decode(&bytes).expect("snapshot decodes");
    assert_eq!(decoded.encode(), bytes, "codec round trip is bit-identical");

    // And the text exposition renders the standard Prometheus shape.
    let prom = wire.render_prometheus();
    assert!(prom.contains("# TYPE unigps_jobs_completed_total counter"), "{prom}");
    assert!(prom.contains("# TYPE unigps_sched_run_time_us histogram"));
    assert!(prom.contains("unigps_sched_run_time_us_bucket{le=\"+Inf\"}"));
    assert!(prom.contains("unigps_sched_run_time_us_count"));

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}

/// `deadline_ms` end to end: the watchdog cancels an overdue job and the
/// typed `Cancelled` error, naming the deadline, crosses the wire.
#[test]
fn deadline_overrun_is_cancelled_by_the_watchdog() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-dl"));
    cfg.slots = 1;
    cfg.total_workers = 2;
    let server = start_server(cfg);

    let mut client = server.client();
    let spec = format!(
        "{}\nalgo = sssp\ndelay_ms = 60000\ndeadline_ms = 300",
        dataset_spec_lines()
    );
    let id = client.submit(&spec).expect("submit");
    let err = client
        .wait(id, Duration::from_secs(30))
        .expect_err("overdue job must be cancelled, not complete");
    assert!(err.is_cancelled(), "{err:?}");
    assert!(err.to_string().contains("deadline exceeded"), "{err}");

    client.shutdown().expect("shutdown");
    drop(client);
    server.join();
}
