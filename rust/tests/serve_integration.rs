//! Integration: the `unigps serve` subsystem end to end — one server
//! thread, concurrent client threads over the Unix-domain socket, mixed
//! operators against one dataset spec. Checks the three serving
//! guarantees: results are bit-identical to direct `engine::run` calls
//! with the same options, the snapshot cache loads the graph exactly once
//! (hit counter = jobs − 1), and the admission queue rejects overload with
//! a typed error instead of buffering it.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use unigps::engine::{EngineKind, RunOptions, RunResult};
use unigps::ipc::shm::ShmMap;
use unigps::operators::{run_operator, Operator};
use unigps::serve::{ServeClient, ServeConfig, Server};
use unigps::session::Session;
use unigps::vcprog::Column;

/// The one dataset spec every job in these tests shares.
const VERTICES: usize = 512;
const EDGES: usize = 2048;
const SEED: u64 = 909;
const JOB_WORKERS: usize = 2;

fn dataset_spec_lines() -> String {
    format!("kind = rmat\nvertices = {VERTICES}\nedges = {EDGES}\nseed = {SEED}\nworkers = {JOB_WORKERS}")
}

/// The graph every spec above resolves to (seeded, so byte-deterministic).
fn dataset_graph() -> unigps::graph::Graph {
    Session::builder().build().generate("rmat", VERTICES, EDGES, SEED)
}

/// (spec suffix, operator, engine) for the mixed workload. Engines vary so
/// the scheduler demonstrably runs heterogeneous backends concurrently.
fn workload() -> Vec<(String, Operator, EngineKind)> {
    vec![
        (
            "algo = pagerank\niterations = 5\nengine = pregel".into(),
            Operator::PageRank { iterations: 5 },
            EngineKind::Pregel,
        ),
        (
            "algo = sssp\nroot = 0\nengine = pushpull".into(),
            Operator::Sssp { root: 0 },
            EngineKind::PushPull,
        ),
        (
            "algo = cc\nengine = gas".into(),
            Operator::ConnectedComponents,
            EngineKind::Gas,
        ),
    ]
}

/// The exact options the scheduler derives for these specs: requested
/// workers (2) ≤ per-slot share, everything else serving defaults.
fn job_options() -> RunOptions {
    RunOptions::default().with_workers(JOB_WORKERS)
}

fn columns_bit_identical(a: &RunResult, b: &RunResult) -> bool {
    a.columns.len() == b.columns.len()
        && a.columns.iter().zip(&b.columns).all(|((an, ac), (bn, bc))| {
            an == bn
                && match (ac, bc) {
                    (Column::I64(x), Column::I64(y)) => x == y,
                    (Column::F64(x), Column::F64(y)) => {
                        x.len() == y.len()
                            && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                    }
                    _ => false,
                }
        })
}

fn start_server(cfg: ServeConfig) -> (PathBuf, std::thread::JoinHandle<()>) {
    let socket = cfg.socket.clone();
    let server = Server::bind(Session::builder().build(), cfg).expect("bind serve socket");
    let handle = std::thread::spawn(move || server.run().expect("serve loop"));
    (socket, handle)
}

/// ≥4 concurrent clients submit mixed pagerank/sssp/cc jobs against the
/// same dataset spec; every result is bit-identical to a direct
/// `engine::run` with the scheduler's options, and the snapshot cache
/// reports exactly one load with hit counter = jobs − 1.
#[test]
fn concurrent_mixed_jobs_share_one_snapshot_and_match_direct_runs() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-int"));
    cfg.slots = 2;
    cfg.queue_cap = 64;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 4; // split 2 ways -> 2 workers per job
    assert_eq!(cfg.per_job_workers(), JOB_WORKERS);
    let (socket, server) = start_server(cfg);

    // Ground truth: direct engine::run dispatch on the same graph with the
    // same options the scheduler derives.
    let graph = dataset_graph();
    let opts = job_options();
    let expected: Vec<RunResult> = workload()
        .iter()
        .map(|(_, op, engine)| run_operator(&graph, op, *engine, &opts).unwrap())
        .collect();
    let expected = Arc::new(expected);

    let clients: usize = 4;
    let jobs_per_client: usize = 3; // 12 jobs total, all three operators each
    std::thread::scope(|s| {
        for c in 0..clients {
            let socket = &socket;
            let expected = expected.clone();
            s.spawn(move || {
                let mut client = ServeClient::connect(socket).expect("connect");
                for j in 0..jobs_per_client {
                    let which = (c + j) % expected.len();
                    let spec =
                        format!("{}\n{}", dataset_spec_lines(), workload()[which].0);
                    let id = client.submit(&spec).expect("submit");
                    let got = client
                        .wait(id, Duration::from_secs(120))
                        .expect("job finishes");
                    assert!(
                        columns_bit_identical(&got, &expected[which]),
                        "client {c} job {j} (workload {which}) diverged from direct run"
                    );
                    assert!(got.metrics.supersteps > 0);
                }
            });
        }
    });

    // Cache accounting: 12 jobs over one (dataset, partition) key.
    let mut client = ServeClient::connect(&socket).expect("connect for stats");
    let stats = client.stats().expect("stats");
    let total_jobs = (clients * jobs_per_client) as u64;
    assert_eq!(stats.jobs.completed, total_jobs, "all jobs completed");
    assert_eq!(stats.jobs.failed, 0);
    assert_eq!(stats.cache.loads, 1, "exactly one snapshot load");
    assert_eq!(stats.cache.misses, 1);
    assert_eq!(
        stats.cache.hits,
        total_jobs - 1,
        "hit counter = jobs - 1 (every job after the first shares the snapshot)"
    );
    assert_eq!(stats.cache.resident, 1);

    client.shutdown().expect("shutdown");
    drop(client);
    server.join().expect("server thread");
    assert!(!socket.exists(), "socket file removed on shutdown");
}

/// Backpressure: with one slot and a two-deep queue, a burst of delayed
/// jobs must produce at least one typed queue-full rejection, while every
/// admitted job still completes and is never silently dropped.
#[test]
fn queue_overload_is_rejected_with_a_typed_error() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-bp"));
    cfg.slots = 1;
    cfg.queue_cap = 2;
    cfg.cache_budget = usize::MAX;
    cfg.total_workers = 2;
    let (socket, server) = start_server(cfg);

    let mut client = ServeClient::connect(&socket).expect("connect");
    // Each job sleeps 400ms before executing, so the single slot cannot
    // drain the burst: capacity is 1 running + 2 queued = 3 of 5.
    let spec = format!("{}\nalgo = sssp\ndelay_ms = 400", dataset_spec_lines());
    let mut admitted = Vec::new();
    let mut rejections = Vec::new();
    for _ in 0..5 {
        match client.submit(&spec) {
            Ok(id) => admitted.push(id),
            Err(e) => rejections.push(e.to_string()),
        }
    }
    assert!(
        !rejections.is_empty(),
        "5 delayed submits into slots=1/queue=2 must overflow"
    );
    // The queue alone admits 2; whether the slot has already popped the
    // first job (admitting a 3rd) is a benign race.
    assert!(admitted.len() >= 2, "queue capacity admits at least 2");
    for r in &rejections {
        assert!(r.contains("queue full"), "typed backpressure rejection, got: {r}");
    }
    for id in &admitted {
        let result = client.wait(*id, Duration::from_secs(120));
        assert!(result.is_ok(), "admitted job {id} must complete: {result:?}");
    }
    let stats = client.stats().expect("stats");
    assert_eq!(stats.jobs.rejected, rejections.len() as u64);
    assert_eq!(stats.jobs.completed, admitted.len() as u64);

    client.shutdown().expect("shutdown");
    drop(client);
    server.join().expect("server thread");
}

/// Status/result error paths over the wire: unknown jobs and not-yet-done
/// results surface as server-side errors, not hangs or garbage.
#[test]
fn wire_error_paths_are_clean() {
    let mut cfg = ServeConfig::new(ShmMap::unique_path("serve-err"));
    cfg.slots = 1;
    cfg.total_workers = 2;
    let (socket, server) = start_server(cfg);

    let mut client = ServeClient::connect(&socket).expect("connect");
    let err = client.status(424242).unwrap_err();
    assert!(err.to_string().contains("unknown job"), "{err}");
    let err = client.result(424242).unwrap_err();
    assert!(err.to_string().contains("unknown job"), "{err}");
    // A bad spec is rejected at submit time with the parse error.
    let err = client.submit("algo = astrology\nvertices = 64").unwrap_err();
    assert!(err.to_string().contains("unknown algo"), "{err}");
    // A job that fails at load time reports Failed + its typed error text.
    let id = client.submit("algo = cc\ndataset = atlantis").expect("admitted");
    let err = client.wait(id, Duration::from_secs(60)).unwrap_err();
    assert!(err.to_string().contains("unknown dataset"), "{err}");

    client.shutdown().expect("shutdown");
    drop(client);
    server.join().expect("server thread");
}
