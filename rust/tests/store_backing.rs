//! Integration: storage-backing equivalence (`docs/storage.md`).
//!
//! The out-of-core subsystem's correctness contract: a graph loaded
//! through any [`StoreMode`] — heap `Vec`s, a zero-copy mmap of a binfmt
//! v2 snapshot, or varint-delta compressed adjacency — must produce
//! **bit-identical** results through every engine, partition strategy,
//! and pipeline mode. The compressed encoding is order-preserving, so
//! even f64 columns (fold-order sensitive) must match to the bit.

use std::path::PathBuf;
use unigps::engine::{run_typed, EngineKind, RunOptions};
use unigps::graph::generate;
use unigps::graph::partition::PartitionStrategy;
use unigps::graph::Graph;
use unigps::store::{snapshot, StoreMode};
use unigps::vcprog::programs::{ConnectedComponents, PageRank, SsspBellmanFord};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("unigps-store-backing-{}-{name}", std::process::id()));
    p
}

/// Pack `g` both raw and compressed, then load it back through all three
/// store modes. The snapshot files are unlinked immediately — on Linux
/// the mmap stays valid for the mapping's lifetime, which doubles as a
/// test that a deleted-but-mapped snapshot keeps serving.
fn variants(g: &Graph, tag: &str) -> Vec<(&'static str, Graph)> {
    let raw = tmp(&format!("{tag}-raw.bin"));
    let packed = tmp(&format!("{tag}-packed.bin"));
    snapshot::pack(g, &raw, false).unwrap();
    snapshot::pack(g, &packed, true).unwrap();
    let heap = snapshot::load(&raw, StoreMode::Heap).unwrap();
    let mmap = snapshot::load(&raw, StoreMode::Mmap).unwrap();
    let comp = snapshot::load(&packed, StoreMode::Compressed).unwrap();
    let _ = std::fs::remove_file(&raw);
    let _ = std::fs::remove_file(&packed);
    assert!(mmap.mapped_bytes() > 0, "mmap variant really is mapped");
    assert_eq!(mmap.heap_bytes(), 0, "mmap variant holds no heap");
    assert!(
        comp.heap_bytes() < heap.heap_bytes(),
        "compressed variant is smaller resident than heap"
    );
    vec![("heap", heap), ("mmap", mmap), ("compressed", comp)]
}

#[test]
fn backings_bit_identical_through_every_engine() {
    let g = generate::random_for_tests(120, 600, 0xD00D);
    let vs = variants(&g, "matrix");
    let engines = [EngineKind::Pregel, EngineKind::Gas, EngineKind::PushPull];
    let strategies = [
        PartitionStrategy::Hash,
        PartitionStrategy::Range,
        PartitionStrategy::EdgeBalanced,
    ];
    for kind in engines {
        for strat in strategies {
            for pipeline in [false, true] {
                let mut o = RunOptions::default().with_workers(3);
                o.partition = strat;
                o.pipeline = pipeline;
                let ctx = |name: &str, algo: &str| {
                    format!("{algo} {kind} {strat:?} pipeline={pipeline} via {name}")
                };

                let want = run_typed(kind, &g, &SsspBellmanFord::new(0), &o).unwrap().props;
                for (name, gv) in &vs {
                    let got = run_typed(kind, gv, &SsspBellmanFord::new(0), &o).unwrap().props;
                    assert_eq!(got, want, "{}", ctx(name, "sssp"));
                }

                let want = run_typed(kind, &g, &ConnectedComponents::new(), &o).unwrap().props;
                for (name, gv) in &vs {
                    let got =
                        run_typed(kind, gv, &ConnectedComponents::new(), &o).unwrap().props;
                    assert_eq!(got, want, "{}", ctx(name, "cc"));
                }

                // PageRank: f64 ranks compared by raw bits — fold order
                // through the backing must match exactly, not just within
                // a tolerance.
                let prog = PageRank::new(g.num_vertices(), 6);
                let mut op = o.clone();
                op.max_iter = prog.rounds();
                let bits = |g: &Graph| -> Vec<u64> {
                    run_typed(kind, g, &prog, &op)
                        .unwrap()
                        .props
                        .iter()
                        .map(|p| p.rank.to_bits())
                        .collect()
                };
                let want = bits(&g);
                for (name, gv) in &vs {
                    assert_eq!(bits(gv), want, "{}", ctx(name, "pagerank"));
                }
            }
        }
    }
}

/// Adversarial shapes for the varint row cursors: a max-degree hub (one
/// giant row spanning many compression blocks), a long path (rows of
/// exactly one edge), and empty rows on the tail vertex.
#[test]
fn backings_agree_on_adversarial_topologies() {
    let graphs = [generate::star(300, true), generate::grid(17, 3, true)];
    for (i, g) in graphs.iter().enumerate() {
        let vs = variants(g, &format!("adversarial-{i}"));
        let o = RunOptions::default().with_workers(2);
        let want = run_typed(EngineKind::Pregel, g, &SsspBellmanFord::new(0), &o)
            .unwrap()
            .props;
        for (name, gv) in &vs {
            assert_eq!(gv.num_vertices(), g.num_vertices(), "{name}");
            assert_eq!(gv.num_edges(), g.num_edges(), "{name}");
            for kind in [EngineKind::Pregel, EngineKind::Gas, EngineKind::PushPull] {
                let got = run_typed(kind, gv, &SsspBellmanFord::new(0), &o).unwrap().props;
                assert_eq!(got, want, "graph {i} via {name} on {kind}");
            }
        }
    }
}

/// The weights column rides the same equivalence: loaded edge properties
/// are bit-identical across backings (mmap reads them zero-copy).
#[test]
fn edge_weights_bit_identical_across_backings() {
    let g = generate::random_for_tests(80, 400, 0xFEED);
    let want: Vec<u64> = g.edge_props().iter().map(|w| w.to_bits()).collect();
    for (name, gv) in variants(&g, "weights") {
        let got: Vec<u64> = gv.edge_props().iter().map(|w| w.to_bits()).collect();
        assert_eq!(got, want, "{name}");
    }
}
