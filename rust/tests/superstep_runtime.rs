//! Integration: the shared superstep runtime behind all distributed
//! engines — cross-engine identity over many random graphs, the
//! overlapped-pipeline vs full-barrier identity property, combiner on/off
//! equivalence, combiner memory shape, and active-bitset convergence
//! behavior.

use unigps::distributed::shared::SharedSlice;
use unigps::engine::superstep::SuperstepRuntime;
use unigps::engine::{run_typed, EngineKind, RunOptions};
use unigps::graph::generate;
use unigps::graph::partition::PartitionStrategy;
use unigps::operators::symmetrized;
use unigps::util::propcheck::{forall, Config};
use unigps::vcprog::programs::{ConnectedComponents, SsspBellmanFord};

/// All three partition strategies, checked exhaustively per case (not one
/// sampled per graph, so every graph×strategy pair is exercised).
const ALL_STRATEGIES: [PartitionStrategy; 3] = [
    PartitionStrategy::Hash,
    PartitionStrategy::Range,
    PartitionStrategy::EdgeBalanced,
];

/// Property: every VCProg engine produces identical results on 50 random
/// graphs, across worker counts and under **every** partition strategy —
/// hash, range and edge-balanced — per graph (all engines run the shared
/// superstep runtime; Serial is the executable specification).
#[test]
fn all_engines_identical_on_50_random_graphs() {
    forall(
        Config::new(50, 0x5EED),
        |rng| {
            let n = 2 + rng.usize_below(120);
            let m = n * (1 + rng.usize_below(5));
            let workers = 1 + rng.usize_below(6);
            (generate::random_for_tests(n, m, rng.next_u64()), workers)
        },
        |(g, workers)| {
            let prog = SsspBellmanFord::new(0);
            // The serial reference is partition-independent; compute once.
            let reference = run_typed(
                EngineKind::Serial,
                g,
                &prog,
                &RunOptions::default().with_workers(*workers),
            )
            .map_err(|e| e.to_string())?
            .props;
            for strategy in ALL_STRATEGIES {
                let mut opts = RunOptions::default().with_workers(*workers);
                opts.partition = strategy;
                for kind in EngineKind::vcprog_engines() {
                    let got = run_typed(kind, g, &prog, &opts)
                        .map_err(|e| e.to_string())?
                        .props;
                    if got != reference {
                        return Err(format!(
                            "{kind} diverged from serial (w={workers}, {strategy:?})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Property: the overlapped per-shard handoff is a pure scheduling change.
/// On the same 50-random-graph corpus shape as the cross-engine identity
/// property, every distributed engine must produce **bit-identical**
/// results — and identical message totals and superstep counts — with the
/// pipeline on and off, with and without the sender-side combiner, under
/// every partition strategy (hash, range, edge-balanced) per graph.
#[test]
fn pipelined_matches_barriered_on_50_random_graphs() {
    forall(
        Config::new(50, 0x0F17),
        |rng| {
            let n = 2 + rng.usize_below(120);
            let m = n * (1 + rng.usize_below(5));
            let workers = 1 + rng.usize_below(6);
            (generate::random_for_tests(n, m, rng.next_u64()), workers)
        },
        |(g, workers)| {
            let prog = SsspBellmanFord::new(0);
            for strategy in ALL_STRATEGIES {
                for kind in [EngineKind::Pregel, EngineKind::Gas, EngineKind::PushPull] {
                    for combiner in [false, true] {
                        let mut over = RunOptions::default().with_workers(*workers);
                        over.partition = strategy;
                        over.combiner = combiner;
                        over.pipeline = true;
                        let mut bar = over.clone();
                        bar.pipeline = false;
                        let a = run_typed(kind, g, &prog, &over).map_err(|e| e.to_string())?;
                        let b = run_typed(kind, g, &prog, &bar).map_err(|e| e.to_string())?;
                        let tag = format!("{kind} w={workers} {strategy:?} combiner={combiner}");
                        if a.props != b.props {
                            return Err(format!("{tag}: pipelined results diverged"));
                        }
                        if a.metrics.total_messages != b.metrics.total_messages {
                            return Err(format!("{tag}: message totals diverged"));
                        }
                        if a.metrics.supersteps != b.metrics.supersteps {
                            return Err(format!("{tag}: superstep counts diverged"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Combiner memory regression: sender-side combine-slot arrays are dense
/// over *local* indices of the destination shard — `partition_size(p)`
/// entries, lazily allocated per peer — never one `|V|`-sized array.
#[test]
fn combiner_slots_are_partition_sized_not_vertex_sized() {
    let n = 103usize;
    let g = generate::random_for_tests(n, 400, 77);
    for strategy in [
        PartitionStrategy::Hash,
        PartitionStrategy::Range,
        PartitionStrategy::EdgeBalanced,
    ] {
        let mut opts = RunOptions::default().with_workers(4);
        opts.partition = strategy;
        opts.combiner = true;
        let topo = g.topology();
        let rt: SuperstepRuntime<'_, i64> = SuperstepRuntime::new(topo, &opts, true);
        let prog = SsspBellmanFord::new(0);
        let mut inbox: Vec<Option<i64>> = (0..n).map(|_| None).collect();
        let inbox_s = SharedSlice::new(&mut inbox);
        let mut ctx = rt.ctx(0);
        // Worker 0 messages every vertex: remote ones go through the
        // combiner, so every remote shard allocates its slot array.
        for dst in 0..n as u32 {
            // SAFETY: single-threaded test; worker 0 owns its send phase.
            unsafe { ctx.route(&prog, inbox_s, 1, dst, 1) };
        }
        let lens = ctx.combine_slot_lens();
        assert_eq!(lens.len(), rt.workers, "{strategy:?}");
        let mut remote_total = 0usize;
        for (p, len) in lens.iter().enumerate() {
            if p == 0 {
                // Local destinations take the inbox fast path and must not
                // allocate combine slots at all.
                assert_eq!(*len, 0, "{strategy:?}: local shard allocated slots");
            } else {
                assert_eq!(
                    *len,
                    rt.part.partition_size(p, n),
                    "{strategy:?}: slot array must be partition_size({p})"
                );
                assert!(*len < n, "{strategy:?}: slot array is |V|-sized");
                remote_total += len;
            }
        }
        assert_eq!(
            remote_total,
            n - rt.part.partition_size(0, n),
            "{strategy:?}: combine memory must be |V| - |V_local|, split per shard"
        );
    }
}

/// Sender-side combining must be a pure optimization: identical results,
/// never more routed messages.
#[test]
fn combiner_on_off_equivalence_property() {
    forall(
        Config::new(20, 0xC0B),
        |rng| {
            let n = 4 + rng.usize_below(100);
            let g = generate::random_for_tests(n, n * 4, rng.next_u64());
            (g, 2 + rng.usize_below(4))
        },
        |(g, workers)| {
            for sym in [false, true] {
                let graph = if sym { symmetrized(g) } else { g.clone() };
                let mut on = RunOptions::default().with_workers(*workers);
                on.combiner = true;
                let mut off = on.clone();
                off.combiner = false;
                if sym {
                    let a = run_typed(EngineKind::Pregel, &graph, &ConnectedComponents::new(), &on)
                        .map_err(|e| e.to_string())?;
                    let b = run_typed(EngineKind::Pregel, &graph, &ConnectedComponents::new(), &off)
                        .map_err(|e| e.to_string())?;
                    if a.props != b.props {
                        return Err("cc: combiner changed results".into());
                    }
                    if a.metrics.total_messages > b.metrics.total_messages {
                        return Err("cc: combiner increased message volume".into());
                    }
                } else {
                    let a = run_typed(EngineKind::Pregel, &graph, &SsspBellmanFord::new(0), &on)
                        .map_err(|e| e.to_string())?;
                    let b = run_typed(EngineKind::Pregel, &graph, &SsspBellmanFord::new(0), &off)
                        .map_err(|e| e.to_string())?;
                    if a.props != b.props {
                        return Err("sssp: combiner changed results".into());
                    }
                    if a.metrics.total_messages > b.metrics.total_messages {
                        return Err("sssp: combiner increased message volume".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// The bitset popcount is the convergence signal: runs that quiesce must
/// report `converged` with a plausible superstep count, on every engine.
#[test]
fn bitset_convergence_detection() {
    // A directed path: SSSP needs exactly len supersteps to quiesce.
    let pairs: Vec<(u32, u32)> = (0..9).map(|i| (i, i + 1)).collect();
    let g = unigps::graph::builder::from_pairs(true, &pairs);
    for kind in EngineKind::vcprog_engines() {
        for workers in [1, 3, 7] {
            for pipeline in [true, false] {
                let mut opts = RunOptions::default().with_workers(workers);
                opts.pipeline = pipeline;
                let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts).unwrap();
                let tag = format!("{kind} w={workers} pipeline={pipeline}");
                assert!(r.metrics.converged, "{tag}");
                // The wave takes 10 steps to cover the path; one more step
                // with zero active vertices closes the run (engine
                // scheduling may save or add a quiesce step, hence the
                // range).
                assert!(
                    (10..=12).contains(&r.metrics.supersteps),
                    "{tag}: {} supersteps",
                    r.metrics.supersteps
                );
                assert_eq!(r.props, (0i64..=9).collect::<Vec<_>>(), "{tag}");
                // The final recorded step must have zero active vertices.
                assert_eq!(r.metrics.steps.last().unwrap().active, 0, "{tag}");
            }
        }
    }
}

/// Per-step message metrics sum exactly to the run total on every engine —
/// the shared runtime keeps the board watermark in a shared atomic, so the
/// accounting holds no matter which thread leads a given round.
#[test]
fn step_messages_sum_to_total_on_all_engines() {
    let g = generate::random_for_tests(90, 700, 0xACC);
    for kind in [EngineKind::Pregel, EngineKind::Gas, EngineKind::PushPull] {
        for pipeline in [true, false] {
            let mut opts = RunOptions::default().with_workers(4);
            opts.pipeline = pipeline;
            let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts).unwrap();
            let per_step: u64 = r.metrics.steps.iter().map(|s| s.messages).sum();
            assert_eq!(per_step, r.metrics.total_messages, "{kind} pipeline={pipeline}");
        }
    }
}
