//! Integration: the shared superstep runtime behind all distributed
//! engines — cross-engine identity over many random graphs, combiner
//! on/off equivalence, and active-bitset convergence behavior.

use unigps::engine::{run_typed, EngineKind, RunOptions};
use unigps::graph::generate;
use unigps::graph::partition::PartitionStrategy;
use unigps::operators::symmetrized;
use unigps::util::propcheck::{forall, Config};
use unigps::vcprog::programs::{ConnectedComponents, SsspBellmanFord};

/// Property: every VCProg engine produces identical results on 50 random
/// graphs, across worker counts and partition strategies (all engines run
/// the shared superstep runtime; Serial is the executable specification).
#[test]
fn all_engines_identical_on_50_random_graphs() {
    forall(
        Config::new(50, 0x5EED),
        |rng| {
            let n = 2 + rng.usize_below(120);
            let m = n * (1 + rng.usize_below(5));
            let workers = 1 + rng.usize_below(6);
            let strategy = *rng.choose(&[
                PartitionStrategy::Hash,
                PartitionStrategy::Range,
                PartitionStrategy::EdgeBalanced,
            ]);
            (generate::random_for_tests(n, m, rng.next_u64()), workers, strategy)
        },
        |(g, workers, strategy)| {
            let mut opts = RunOptions::default().with_workers(*workers);
            opts.partition = *strategy;
            let prog = SsspBellmanFord::new(0);
            let reference = run_typed(EngineKind::Serial, g, &prog, &opts)
                .map_err(|e| e.to_string())?
                .props;
            for kind in EngineKind::vcprog_engines() {
                let got = run_typed(kind, g, &prog, &opts)
                    .map_err(|e| e.to_string())?
                    .props;
                if got != reference {
                    return Err(format!("{kind} diverged from serial (w={workers}, {strategy:?})"));
                }
            }
            Ok(())
        },
    );
}

/// Sender-side combining must be a pure optimization: identical results,
/// never more routed messages.
#[test]
fn combiner_on_off_equivalence_property() {
    forall(
        Config::new(20, 0xC0B),
        |rng| {
            let n = 4 + rng.usize_below(100);
            let g = generate::random_for_tests(n, n * 4, rng.next_u64());
            (g, 2 + rng.usize_below(4))
        },
        |(g, workers)| {
            for sym in [false, true] {
                let graph = if sym { symmetrized(g) } else { g.clone() };
                let mut on = RunOptions::default().with_workers(*workers);
                on.combiner = true;
                let mut off = on.clone();
                off.combiner = false;
                if sym {
                    let a = run_typed(EngineKind::Pregel, &graph, &ConnectedComponents::new(), &on)
                        .map_err(|e| e.to_string())?;
                    let b = run_typed(EngineKind::Pregel, &graph, &ConnectedComponents::new(), &off)
                        .map_err(|e| e.to_string())?;
                    if a.props != b.props {
                        return Err("cc: combiner changed results".into());
                    }
                    if a.metrics.total_messages > b.metrics.total_messages {
                        return Err("cc: combiner increased message volume".into());
                    }
                } else {
                    let a = run_typed(EngineKind::Pregel, &graph, &SsspBellmanFord::new(0), &on)
                        .map_err(|e| e.to_string())?;
                    let b = run_typed(EngineKind::Pregel, &graph, &SsspBellmanFord::new(0), &off)
                        .map_err(|e| e.to_string())?;
                    if a.props != b.props {
                        return Err("sssp: combiner changed results".into());
                    }
                    if a.metrics.total_messages > b.metrics.total_messages {
                        return Err("sssp: combiner increased message volume".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// The bitset popcount is the convergence signal: runs that quiesce must
/// report `converged` with a plausible superstep count, on every engine.
#[test]
fn bitset_convergence_detection() {
    // A directed path: SSSP needs exactly len supersteps to quiesce.
    let pairs: Vec<(u32, u32)> = (0..9).map(|i| (i, i + 1)).collect();
    let g = unigps::graph::builder::from_pairs(true, &pairs);
    for kind in EngineKind::vcprog_engines() {
        for workers in [1, 3, 7] {
            let opts = RunOptions::default().with_workers(workers);
            let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &opts).unwrap();
            assert!(r.metrics.converged, "{kind} w={workers}");
            // The wave takes 10 steps to cover the path; one more step with
            // zero active vertices closes the run (engine scheduling may
            // save or add a quiesce step, hence the range).
            assert!(
                (10..=12).contains(&r.metrics.supersteps),
                "{kind} w={workers}: {} supersteps",
                r.metrics.supersteps
            );
            assert_eq!(r.props, (0i64..=9).collect::<Vec<_>>(), "{kind}");
            // The final recorded step must have zero active vertices.
            assert_eq!(r.metrics.steps.last().unwrap().active, 0, "{kind}");
        }
    }
}

/// Per-step message metrics sum exactly to the run total on every engine —
/// the shared runtime keeps the board watermark in a shared atomic, so the
/// accounting holds no matter which thread leads a given round.
#[test]
fn step_messages_sum_to_total_on_all_engines() {
    let g = generate::random_for_tests(90, 700, 0xACC);
    for kind in [EngineKind::Pregel, EngineKind::Gas, EngineKind::PushPull] {
        let r = run_typed(kind, &g, &SsspBellmanFord::new(0), &RunOptions::default().with_workers(4))
            .unwrap();
        let per_step: u64 = r.metrics.steps.iter().map(|s| s.messages).sum();
        assert_eq!(per_step, r.metrics.total_messages, "{kind}");
    }
}
