//! Integration: the tensor engine (L1 Pallas + L2 JAX artifacts via PJRT)
//! against the interpreted engines and serial oracles. Skips gracefully
//! when `make artifacts` hasn't run.

use unigps::engine::{baselines, EngineKind};
use unigps::graph::generate;
use unigps::operators::{Operator, OperatorBuilder};
use unigps::util::propcheck::{forall, Config};

fn have_artifacts() -> bool {
    unigps::engine::tensor::artifacts_dir().join("manifest.json").exists()
}

#[test]
fn tensor_sssp_matches_dijkstra_property() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    forall(
        Config::new(4, 0xE0),
        |rng| {
            let n = 20 + rng.usize_below(400);
            generate::random_for_tests(n, n * 4, rng.next_u64())
        },
        |g| {
            let t = OperatorBuilder::new(g, Operator::Sssp { root: 0 })
                .engine(EngineKind::Tensor)
                .run()
                .map_err(|e| e.to_string())?;
            let got = t.column("distance").unwrap().as_i64().unwrap();
            let want = baselines::dijkstra(g, 0);
            if got != &want[..] {
                return Err("tensor sssp != dijkstra".into());
            }
            Ok(())
        },
    );
}

#[test]
fn tensor_cc_matches_union_find_property() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    forall(
        Config::new(4, 0xE1),
        |rng| {
            let n = 20 + rng.usize_below(300);
            // Sparse so multiple components exist.
            generate::random_for_tests(n, n / 2 + 1, rng.next_u64())
        },
        |g| {
            let sym = unigps::operators::symmetrized(g);
            let t = OperatorBuilder::new(g, Operator::ConnectedComponents)
                .engine(EngineKind::Tensor)
                .run()
                .map_err(|e| e.to_string())?;
            let got = t.column("component").unwrap().as_i64().unwrap();
            let want: Vec<i64> = baselines::connected_components(&sym)
                .into_iter()
                .map(|c| c as i64)
                .collect();
            if got != &want[..] {
                return Err("tensor cc != union-find".into());
            }
            Ok(())
        },
    );
}

#[test]
fn tensor_pagerank_matches_power_iteration() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let g = generate::random_for_tests(250, 2000, 0xE2);
    let t = OperatorBuilder::new(&g, Operator::PageRank { iterations: 12 })
        .engine(EngineKind::Tensor)
        .run()
        .unwrap();
    let got = t.column("rank").unwrap().as_f64().unwrap();
    let want = baselines::pagerank(&g, 0.85, 12);
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        let scale = a.abs().max(b.abs()).max(1e-12);
        assert!((a - b).abs() / scale < 1e-3, "v{i}: {a} vs {b}");
    }
}

#[test]
fn tensor_bucket_reuse_is_cached() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Two graphs in the same bucket: second run must not recompile (fast).
    let g1 = generate::random_for_tests(100, 500, 1);
    let g2 = generate::random_for_tests(120, 600, 2);
    let t = std::time::Instant::now();
    OperatorBuilder::new(&g1, Operator::Sssp { root: 0 })
        .engine(EngineKind::Tensor)
        .run()
        .unwrap();
    let first = t.elapsed();
    let t = std::time::Instant::now();
    OperatorBuilder::new(&g2, Operator::Sssp { root: 0 })
        .engine(EngineKind::Tensor)
        .run()
        .unwrap();
    let second = t.elapsed();
    // Compilation dominates the first run; the second should be faster or
    // at least not dramatically slower.
    assert!(
        second < first * 3,
        "expected compiled-step reuse: first {first:?}, second {second:?}"
    );
}
